"""Observability endpoint tests: route behavior on a standalone server
(Prometheus text, JSON snapshot, trace spans, health verdicts, 404/400),
the lag_health degraded logic, and one full-stack run — a windowed pipeline
consuming over the socket transport with a durable state store and a
delivery lane, every layer's metrics and the batch-epoch spans read back
through a live HTTP scrape (the issue's acceptance scenario).
"""
import json
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import Broker, Context, LagPolicy, StreamingContext
from repro.data import (DurableStateStore, IngestConfig, IngestRunner,
                        MetricsRegistry, ProjectionSource, SinkPolicy,
                        TraceLog, WindowSpec, set_registry, windowed)
from repro.data.metrics import SPAN_STAGES
from repro.data.obs_server import ObservabilityServer, lag_health
from repro.data.transport import RemoteBroker, serve_broker


@pytest.fixture
def registry():
    """Fresh process-wide registry per test: components constructed inside
    the test register here, not into state leaked by earlier tests."""
    reg = MetricsRegistry()
    prev = set_registry(reg)
    yield reg
    set_registry(prev)


def _get(url, timeout=10):
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.status, r.read()


def _get_json(url):
    status, body = _get(url)
    return status, json.loads(body)


# -- routes on a standalone server -------------------------------------------

def test_all_routes_serve(registry):
    registry.counter("hits_total", "requests").inc(5)
    registry.gauge("depth", callback=lambda: 3)
    registry.histogram("lat_seconds").observe(0.01)
    traces = TraceLog()
    rec = traces.begin(0, 8)
    rec.add("batch_fn", 0.1)
    rec.finish(epoch=1)
    with ObservabilityServer(registry, traces=traces) as srv:
        status, text = _get(srv.url + "/metrics")
        text = text.decode()
        assert status == 200
        assert "repro_hits_total 5" in text
        assert "repro_depth 3" in text
        assert "repro_lat_seconds_count 1" in text

        status, snap = _get_json(srv.url + "/metrics.json")
        assert status == 200
        names = {m["name"] for m in snap["metrics"]}
        assert names == {"hits_total", "depth", "lat_seconds"}
        # each scrape samples first: two scrapes -> two series points
        assert all(len(m["series"]) == 2 for m in snap["metrics"])

        status, spans = _get_json(srv.url + "/traces")
        assert status == 200
        assert spans["recorded"] == 1
        assert spans["spans"][0]["epoch"] == 1
        assert spans["spans"][0]["stages"]["batch_fn"] == pytest.approx(0.1)

        status, health = _get_json(srv.url + "/health")
        assert status == 200                  # no health_fn -> always ok
        assert health == {"status": "ok", "topics": {}}


def test_unknown_route_404_lists_routes(registry):
    with ObservabilityServer(registry) as srv:
        with pytest.raises(urllib.error.HTTPError) as e:
            _get(srv.url + "/nope")
        assert e.value.code == 404
        body = json.loads(e.value.read())
        assert "/metrics" in body["routes"] and "/health" in body["routes"]


def test_traces_bad_last_is_400_and_last_n_limits(registry):
    traces = TraceLog()
    for i in range(5):
        traces.begin(i, 1).finish(epoch=i + 1)
    with ObservabilityServer(registry, traces=traces) as srv:
        with pytest.raises(urllib.error.HTTPError) as e:
            _get(srv.url + "/traces?last=abc")
        assert e.value.code == 400
        status, body = _get_json(srv.url + "/traces?last=2")
        assert status == 200
        assert [s["batch_index"] for s in body["spans"]] == [3, 4]
        assert body["recorded"] == 5


def test_start_is_idempotent_and_stop_releases(registry):
    srv = ObservabilityServer(registry).start()
    addr = srv.address
    assert srv.start() is srv and srv.address == addr
    url = srv.url
    srv.stop()
    srv.stop()                                # idempotent
    with pytest.raises(urllib.error.URLError):
        _get(url + "/health", timeout=2)
    with pytest.raises(RuntimeError):
        ObservabilityServer(registry).url     # not started: no address yet


# -- health verdicts ----------------------------------------------------------

def test_lag_health_degrades_on_watermark(registry):
    lags = {"frames": 0}
    policy = LagPolicy(100, 10, sustain=3, cooldown=5.0)
    with ObservabilityServer(
            registry, health_fn=lag_health(lambda: lags, policy)) as srv:
        status, body = _get_json(srv.url + "/health")
        assert status == 200
        assert body["topics"]["frames"] == {
            "lag": 0, "scale_up_lag": 100, "scale_down_lag": 10, "ok": True}

        lags["frames"] = 100                  # at the scale-up watermark
        with pytest.raises(urllib.error.HTTPError) as e:
            _get(srv.url + "/health")
        assert e.value.code == 503
        body = json.loads(e.value.read())
        assert body["status"] == "degraded"
        assert body["topics"]["frames"]["ok"] is False


def test_lag_health_without_policy_never_degrades():
    health = lag_health(lambda: {"t": 10 ** 9})
    assert health()["status"] == "ok"


def test_lag_health_survives_torn_down_context():
    def lag_of():
        raise RuntimeError("context closed")
    verdict = lag_health(lag_of, LagPolicy(100, 10))()
    assert verdict["status"] == "degraded"
    assert "context closed" in verdict["error"]


# -- full stack: every layer visible through one live scrape ------------------

def test_windowed_pipeline_over_transport_exposes_every_layer(
        registry, tmp_path):
    """ProjectionSource -> IngestRunner -> BrokerServer/RemoteBroker ->
    windowed batch fn with a DurableStateStore -> delivery lane, observed
    live: broker, transport, ingest, delivery, state, and stream metrics all
    present on ``/metrics``, batch spans on ``/traces`` tagged with the
    checkpoint epoch, ``/health`` judged against the lag policy."""
    broker = Broker()
    server = serve_broker(broker, str(tmp_path / "b.sock"))
    client = RemoteBroker(server.address)
    sc = StreamingContext(Context(), client, max_records_per_partition=8,
                          checkpoint_path=str(tmp_path / "ckpt"))
    try:
        runner = IngestRunner(client, consumer=sc)
        runner.add(ProjectionSource(np.arange(64.0).reshape(64, 1)),
                   IngestConfig(topic="frames", poll_batch=16,
                                flush_records=8))
        sc.subscribe(["frames"])
        windows = []
        store = DurableStateStore(str(tmp_path / "state"))
        sc.foreach_batch(windowed(
            WindowSpec(size=16),
            lambda recs, info: windows.append(len(recs)), store=store))
        sc.add_sink(lambda info: None, policy=SinkPolicy(), name="probe")
        policy = LagPolicy(1000, 10, sustain=3, cooldown=5.0)
        obs = sc.serve_observability(("127.0.0.1", 0), lag_policy=policy)
        assert sc.serve_observability() is obs          # idempotent

        ticks = 0
        while not (runner.done and sc.lag("frames") == 0):
            runner.pump()
            sc.run_one_batch()
            ticks += 1
            assert ticks < 500, "pipeline never drained"
        assert windows == [16, 16, 16, 16]
        assert sc.delivery.drain(timeout=10)

        # one scrape carries every instrumented layer (repro_ namespace)
        _, text = _get(obs.url + "/metrics")
        text = text.decode()
        for line in (
                'repro_broker_produce_records_total{topic="frames"} 64',
                'repro_broker_read_records_total{topic="frames"} 64',
                'repro_broker_lag{topic="frames"} 0',
                "repro_transport_requests_total",
                "repro_transport_bytes_received_total",
                "repro_transport_connections 1",
                'repro_ingest_produced_records_total{topic="frames"} 64',
                'repro_ingest_flush_records_count{topic="frames"} 8',
                'repro_ingest_lag{topic="frames"} 0',
                'repro_delivery_enqueued_total{lane="probe"} 8',
                'repro_delivery_delivered_total{lane="probe"} 8',
                'repro_delivery_queue_depth{lane="probe"} 0',
                "repro_state_commits_total 8",
                "repro_state_commit_seconds_count 8",
                "repro_state_log_bytes",
                "repro_stream_batches_total 8",
                "repro_stream_records_total 64",
                "repro_stream_epoch 8",
                'repro_stream_lag{topic="frames"} 0',
        ):
            assert line in text, f"missing from /metrics: {line}"

        # spans: one per committed batch, stamped with its checkpoint epoch
        _, body = _get_json(obs.url + "/traces?last=100")
        spans = body["spans"]
        assert len(spans) == 8 and body["recorded"] == 8
        assert [s["epoch"] for s in spans] == list(range(1, 9))
        assert all(s["num_records"] == 8 for s in spans)
        assert set(spans[-1]["stages"]) == set(SPAN_STAGES)
        assert all(s["total_s"] >= sum(s["stages"].values()) * 0.5
                   for s in spans)

        # the satellite: server-side counters over the wire
        stats = client.stats()
        # batched produce_many keeps this well under one request per record
        assert 0 < stats["requests_served"] < 64
        assert stats["frames_rejected"] == 0
        assert stats["connections"] >= 1

        status, health = _get_json(obs.url + "/health")
        assert status == 200
        assert health["status"] == "ok"
        assert health["topics"]["frames"]["lag"] == 0

        url = obs.url
        sc.close()                             # stops the endpoint too
        with pytest.raises(urllib.error.URLError):
            _get(url + "/health", timeout=2)
    finally:
        sc.close()
        client.close()
        server.stop()
