"""Optimizer substrate: AdamW vs a NumPy reference, schedules, clipping,
int8 gradient compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container has no hypothesis; smoke path below
    HAVE_HYPOTHESIS = False

from repro.configs.base import OptimizerConfig
from repro.optim import (adamw_update, clip_by_global_norm, dequantize_int8,
                         ef_compress_tree, init_opt_state, init_residual,
                         lr_schedule, quantize_int8)


def numpy_adamw(p, g, m, v, step, cfg):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** step)
    vh = v / (1 - cfg.b2 ** step)
    delta = mh / (np.sqrt(vh) + cfg.eps)
    lr = float(lr_schedule(jnp.asarray(step), cfg))
    if p.ndim >= 2:
        delta = delta + cfg.weight_decay * p
    return p - lr * delta, m, v


def test_adamw_matches_numpy_reference():
    cfg = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=10 ** 9,
                          grad_clip=0.0, master_fp32=True)
    rng = np.random.default_rng(0)
    p0 = rng.standard_normal((4, 6)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    state = init_opt_state(params, cfg)
    p_ref, m_ref, v_ref = p0.copy(), np.zeros_like(p0), np.zeros_like(p0)
    for step in range(1, 6):
        g = rng.standard_normal((4, 6)).astype(np.float32)
        params, state, _ = adamw_update(params, {"w": jnp.asarray(g)},
                                        state, cfg)
        p_ref, m_ref, v_ref = numpy_adamw(p_ref, g, m_ref, v_ref, step, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), p_ref,
                                   rtol=2e-5, atol=2e-6)


def test_grad_clip_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = np.sqrt(sum(float(jnp.sum(jnp.square(x)))
                        for x in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(float(norm), np.sqrt(250.0), rtol=1e-6)
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.asarray(s), cfg)) for s in range(101)]
    assert lrs[0] == 0.0
    np.testing.assert_allclose(lrs[10], 1.0, rtol=1e-6)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))
    np.testing.assert_allclose(lrs[100], 0.1, rtol=1e-5)


def _check_int8_quantization_error_bound(xs):
    """|x - deq(quant(x))| <= scale/2 elementwise (symmetric rounding)."""
    x = jnp.asarray(xs, jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    assert np.all(err <= float(scale) * 0.5 + 1e-7)


def test_int8_quantization_error_bound_smoke():
    """Deterministic replicas of the hypothesis property (runs everywhere)."""
    rng = np.random.default_rng(11)
    for xs in ([0.0], [1e3, -1e3], rng.uniform(-1e3, 1e3, 64).tolist(),
               rng.uniform(-1e-3, 1e-3, 17).tolist()):
        _check_int8_quantization_error_bound(xs)


if HAVE_HYPOTHESIS:
    @given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                    min_size=1, max_size=64))
    @settings(max_examples=40, deadline=None)
    def test_property_int8_quantization_error_bound(xs):
        _check_int8_quantization_error_bound(xs)


def test_error_feedback_compensates_bias():
    """With error feedback, the accumulated applied updates converge to the
    accumulated true gradients (bounded residual) — the EF-SGD guarantee."""
    rng = np.random.default_rng(1)
    grads_seq = [rng.standard_normal((32,)).astype(np.float32) * 0.1
                 for _ in range(50)]
    params = {"w": jnp.zeros((32,))}
    residual = init_residual(params)
    applied = np.zeros((32,), np.float32)
    for g in grads_seq:
        _, residual, deq = ef_compress_tree({"w": jnp.asarray(g)}, residual)
        applied += np.asarray(deq["w"])
    true_sum = np.sum(grads_seq, axis=0)
    # residual bounds the gap; without EF the bias would accumulate over steps
    gap = np.abs(applied - true_sum)
    res = np.abs(np.asarray(residual["w"]))
    np.testing.assert_allclose(gap, res, rtol=1e-4, atol=1e-5)
    assert np.max(gap) < 0.05 * np.max(np.abs(true_sum)) + 0.05


def test_zero1_specs_shard_over_data():
    from jax.sharding import PartitionSpec as P
    from repro.optim import zero1_state_specs

    class FakeMesh:
        shape = {"data": 4, "model": 2}
        axis_names = ("data", "model")

    pspecs = {"w": P(None, "model"), "b": P(), "e": P("data", None)}
    shapes = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32),
              "b": jax.ShapeDtypeStruct((3,), jnp.float32),
              "e": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    cfg = OptimizerConfig(zero1=True)
    state = zero1_state_specs(pspecs, shapes, FakeMesh(), cfg)
    assert state["m"]["w"] == P("data", "model")
    assert state["m"]["b"] == P()          # 3 % 4 != 0 -> unsharded
    assert state["m"]["e"] == P("data", None)  # no duplicate 'data'
    assert state["step"] == P()
