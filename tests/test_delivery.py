"""Chaos suite for the parallel sink delivery runtime: slow sinks must not
stall fast lanes, crashing sinks are isolated (skip / dead-letter /
fail-pipeline per policy), queue-full block vs drop semantics hold, and a
clean close() drains every lane without losing batches or leaking threads.
"""
import threading
import time

import pytest

from repro.core import (Broker, NearRealTimePipeline, PipelineConfig,
                        StreamingContext, Context)
from repro.data import (DeliveryFailed, DeliveryRuntime, MetricsSink,
                        SinkPolicy, SyntheticRateSource)


class ChaosSink:
    """Keyed sink with injectable latency and failures."""

    def __init__(self, sleep: float = 0.0, fail: bool = False,
                 fail_first: int = 0) -> None:
        self.sleep = sleep
        self.fail = fail
        self.fail_first = fail_first     # fail the first N calls, then heal
        self.calls = 0
        self.batches: list[list] = []
        self.closed = False
        self._lock = threading.Lock()

    def write_batch(self, items):
        with self._lock:
            self.calls += 1
            calls = self.calls
        if self.sleep:
            time.sleep(self.sleep)
        if self.fail or calls <= self.fail_first:
            raise RuntimeError(f"chaos(call={calls})")
        with self._lock:
            self.batches.append(list(items))
        return len(items)

    def close(self):
        self.closed = True


class FakeInfo:
    """Minimal BatchInfo stand-in for driving the runtime directly."""

    def __init__(self, index):
        self.index = index
        self.result = [(f"k{index:04d}", index)]
        self.num_records = 1
        self.processing_time = 0.001


def _submit_all(runtime, n):
    for i in range(n):
        runtime.submit(FakeInfo(i))


def _pipeline(broker, total, sinks, interval=0.005):
    """Source -> trivial keyed process -> the given sinks/(sink, policy)s."""
    return NearRealTimePipeline(
        broker,
        PipelineConfig(batch_interval=interval, max_records_per_partition=4),
        lambda rdd, info, bridge: [(f"rec-{v:04d}", v)
                                   for v in rdd.collect()],
        sources=[SyntheticRateSource(rate=1e9, total=total)],
        sinks=sinks)


# -- chaos: the slow sink -----------------------------------------------------

def test_slow_sink_does_not_stall_fast_lane():
    """One sink sleeping 100x the batch interval: the fast lane's per-batch
    delivery latency stays within 2x the all-fast baseline (plus scheduler
    slack), nowhere near the slow sink's serial cost."""
    interval = 0.005
    slow_s = 100 * interval
    batches = 8

    def run(slow_sleep):
        fast = ChaosSink()
        slow = ChaosSink(sleep=slow_sleep)
        rt = DeliveryRuntime()
        rt.add_sink(fast, SinkPolicy.skip_batch(queue_depth=batches),
                    name="fast")
        rt.add_sink(slow, SinkPolicy.skip_batch(queue_depth=batches,
                                                on_full="block"),
                    name="slow")
        _submit_all(rt, batches)
        # metrics path = the fast lane: wait only for it
        deadline = time.monotonic() + 5
        while (len(fast.batches) < batches
               and time.monotonic() < deadline):
            time.sleep(0.001)
        fast_latency = max(rt.lanes[0].metrics.latencies, default=0.0)
        rt.close(drain=True)
        assert len(fast.batches) == batches
        return fast_latency, slow

    baseline, _ = run(0.0)
    chaos, slow = run(slow_s)
    # 2x the baseline, with a floor absorbing scheduler jitter on a loaded
    # CI box; the real claim is the fast lane never waits on the slow one
    assert chaos <= max(2 * baseline, 0.05)
    assert chaos < slow_s                       # not even ONE slow write
    assert len(slow.batches) == batches         # and the slow lane drained


def test_slow_sink_pipeline_end_to_end_latency():
    """Same claim through NearRealTimePipeline: streaming wall-clock with a
    100x-slow policy'd sink stays within 2x the all-fast run, far under the
    slow sink's serial cost, and close() still lands every batch."""
    interval = 0.005
    slow_s = 100 * interval

    def run(slow_sleep):
        fast = ChaosSink()
        slow = ChaosSink(sleep=slow_sleep)
        metrics = MetricsSink()
        pipe = _pipeline(
            Broker(), 24,
            [metrics,
             (fast, SinkPolicy.skip_batch(queue_depth=64)),
             (slow, SinkPolicy.skip_batch(queue_depth=64))],
            interval=interval)
        t0 = time.perf_counter()
        report = pipe.run_until_drained()
        wall = time.perf_counter() - t0
        pipe.close(drain=True)
        assert report.records == 24
        assert len(slow.batches) == report.batches   # drained at close
        return wall, report.batches

    base_wall, base_batches = run(0.0)
    chaos_wall, chaos_batches = run(slow_s)
    serial_cost = chaos_batches * slow_s
    assert chaos_wall <= max(2 * base_wall, base_wall + 0.25)
    assert chaos_wall < serial_cost / 2


# -- chaos: the crashing sink -------------------------------------------------

def test_crashing_sink_dead_letters_and_pipeline_completes():
    broker = Broker()
    good = ChaosSink()
    bad = ChaosSink(fail=True)
    pipe = _pipeline(
        broker, 20,
        [good,
         (bad, SinkPolicy.dead_letter("dlq", retries=1, queue_depth=64))])
    report = pipe.run_until_drained()
    pipe.close(drain=True)                       # completes, does NOT raise
    assert report.records == 20                  # pipeline reports success
    lane = pipe.delivery_report()["ChaosSink"]
    assert lane["failed"] == report.batches
    assert lane["dead_lettered"] == report.batches
    assert lane["retries"] == report.batches     # one retry each, then DLQ
    # every failed batch's items landed on the dead-letter topic, key intact
    from repro.core import OffsetRange
    n = broker.end_offset("dlq")
    assert n == report.records
    recs = broker.read(OffsetRange("dlq", 0, 0, n))
    assert {r.key for r in recs} == {f"rec-{v:04d}".encode()
                                     for v in range(20)}
    assert all(r.value["sink"] == "ChaosSink" and "chaos" in r.value["error"]
               for r in recs)
    assert sorted(r.value["value"] for r in recs) == list(range(20))
    # the healthy sink never noticed
    assert sum(len(b) for b in good.batches) == 20


def test_retry_then_success_recovers_without_dead_letter():
    broker = Broker()
    flaky = ChaosSink(fail_first=2)              # first two calls fail
    rt = DeliveryRuntime(broker)
    rt.add_sink(flaky, SinkPolicy.retry(3, then="dead_letter",
                                        dead_letter_topic="dlq"))
    rt.submit(FakeInfo(0))
    rt.close(drain=True)
    m = rt.lanes[0].metrics
    assert m.delivered == 1 and m.failed == 0 and m.dead_lettered == 0
    assert m.retries == 2
    assert "dlq" not in broker.topics()          # never needed


def test_fail_pipeline_policy_aborts():
    pipe = _pipeline(
        Broker(), 40,
        [(ChaosSink(fail=True), SinkPolicy.fail_pipeline(queue_depth=64))])
    with pytest.raises(DeliveryFailed):
        pipe.run_until_drained()
        pipe.close(drain=True)   # if the run outraced the lane, close raises


def test_blocked_enqueue_is_interrupted_by_fail_pipeline():
    """Batch thread blocked in a full on_full="block" queue while ANOTHER
    lane's fail_pipeline verdict lands: the blocked submit must raise
    DeliveryFailed promptly instead of waiting out the wedged sink."""
    rt = DeliveryRuntime()
    rt.add_sink(ChaosSink(sleep=5.0),
                SinkPolicy.skip_batch(queue_depth=1, on_full="block"),
                name="wedged")
    rt.add_sink(ChaosSink(sleep=0.2, fail=True),
                SinkPolicy.fail_pipeline(queue_depth=8), name="fatal")
    t0 = time.perf_counter()
    with pytest.raises(DeliveryFailed):
        _submit_all(rt, 4)     # blocks on lane "wedged" by the 3rd submit
    assert time.perf_counter() - t0 < 2.0
    assert rt.report()["fatal"]["failed"] >= 1
    with pytest.raises(DeliveryFailed):     # close re-raises the verdict
        rt.close(drain=False, timeout=0.5)


def test_zero_timeout_means_immediate_deadline_not_infinite():
    wedged = ChaosSink(sleep=30.0)
    rt = DeliveryRuntime()
    lane = rt.add_sink(wedged, SinkPolicy.skip_batch(queue_depth=1,
                                                     on_full="drop"))
    _submit_all(rt, 3)
    time.sleep(0.05)           # worker wedges; queue stays full
    t0 = time.perf_counter()
    assert rt.drain(timeout=0.0) is False
    rt.close(drain=False, timeout=0.0)
    assert time.perf_counter() - t0 < 0.5
    assert lane.metrics.leaked_thread


def test_skip_batch_isolates_failures_to_one_lane():
    rt = DeliveryRuntime()
    good, bad = ChaosSink(), ChaosSink(fail=True)
    rt.add_sink(good, SinkPolicy.skip_batch(), name="good")
    rt.add_sink(bad, SinkPolicy.skip_batch(), name="bad")
    _submit_all(rt, 12)
    rt.close(drain=True)
    assert len(good.batches) == 12
    rep = rt.report()
    assert rep["bad"]["failed"] == 12 and rep["bad"]["delivered"] == 0
    assert rep["good"]["failed"] == 0 and rep["good"]["delivered"] == 12


# -- queue-full semantics -----------------------------------------------------

def test_queue_full_drop_sheds_batches():
    slow = ChaosSink(sleep=0.02)
    rt = DeliveryRuntime()
    lane = rt.add_sink(slow, SinkPolicy.skip_batch(queue_depth=2,
                                                   on_full="drop"))
    t0 = time.perf_counter()
    _submit_all(rt, 12)
    submit_wall = time.perf_counter() - t0
    rt.close(drain=True)
    m = lane.metrics
    assert submit_wall < 0.02 * 6                # submits never blocked long
    assert m.dropped_full > 0                    # pressure was shed...
    assert m.delivered + m.dropped_full == 12    # ...and fully accounted
    assert len(slow.batches) == m.delivered


def test_queue_full_block_applies_backpressure_and_loses_nothing():
    slow = ChaosSink(sleep=0.02)
    rt = DeliveryRuntime()
    lane = rt.add_sink(slow, SinkPolicy.skip_batch(queue_depth=2,
                                                   on_full="block"))
    t0 = time.perf_counter()
    _submit_all(rt, 10)
    submit_wall = time.perf_counter() - t0
    rt.close(drain=True)
    assert submit_wall >= 0.02 * 4               # the batch thread DID wait
    assert lane.metrics.dropped_full == 0
    assert len(slow.batches) == 10               # lossless


# -- timeouts -----------------------------------------------------------------

def test_sink_timeout_is_a_failure_and_wedged_lane_fails_fast():
    broker = Broker()
    stuck = ChaosSink(sleep=0.5)
    rt = DeliveryRuntime(broker)
    lane = rt.add_sink(
        stuck, SinkPolicy.dead_letter("dlq", timeout=0.05, queue_depth=8))
    _submit_all(rt, 3)
    rt.drain(timeout=2)
    t0 = time.perf_counter()
    rt.close(drain=True, timeout=2.0)
    assert time.perf_counter() - t0 < 2.5        # close never hung on it
    m = lane.metrics
    assert m.delivered == 0 and m.failed == 3    # timeout + 2x wedged
    assert m.dead_lettered == 3
    assert broker.end_offset("dlq") == 3
    assert "Timeout" in m.last_error or "wedged" in m.last_error


# -- clean shutdown -----------------------------------------------------------

def test_close_drains_all_lanes_no_lost_batches_no_leaked_threads():
    before = threading.active_count()
    sinks = [ChaosSink(), ChaosSink(sleep=0.005), ChaosSink()]
    rt = DeliveryRuntime()
    lanes = [rt.add_sink(s, SinkPolicy.skip_batch(queue_depth=64),
                         name=f"lane-{i}") for i, s in enumerate(sinks)]
    _submit_all(rt, 20)
    rt.close(drain=True)
    for sink, lane in zip(sinks, lanes):
        assert len(sink.batches) == 20           # no lost batches
        assert sink.closed                       # sink.close() propagated
        assert not lane.thread.is_alive()        # no leaked threads
        assert not lane.metrics.leaked_thread
    assert threading.active_count() == before
    rt.close(drain=True)                         # idempotent


def test_close_honors_timeout_with_wedged_sink_and_full_queue():
    """A sink hung in write_batch with a full lane queue: close() must
    return within its timeout (abandoning the daemon worker), not block
    forever on the shutdown sentinel."""
    wedged = ChaosSink(sleep=30.0)
    rt = DeliveryRuntime()
    lane = rt.add_sink(wedged, SinkPolicy.skip_batch(queue_depth=1,
                                                     on_full="drop"))
    _submit_all(rt, 3)          # 1 in flight (hung), 1 queued, 1 dropped
    time.sleep(0.05)            # let the worker wedge into the sleep
    t0 = time.perf_counter()
    rt.close(drain=True, timeout=0.3)
    assert time.perf_counter() - t0 < 1.0
    assert lane.metrics.leaked_thread


def test_metrics_sink_with_policy_keeps_both_surfaces():
    """MetricsSink exposes observe AND write_batch; the policy path must
    register both (an observe lane and a keyed lane), like the serial path."""
    metrics = MetricsSink()
    pipe = _pipeline(Broker(), 12, [(metrics, SinkPolicy.skip_batch())])
    report = pipe.run_until_drained()
    pipe.close(drain=True)
    assert metrics.batches == report.batches     # observe lane ran
    assert metrics.items == 12                   # keyed lane ran too
    assert set(pipe.delivery_report()) == {"MetricsSink-observe",
                                           "MetricsSink"}


def test_close_without_drain_discards_fast():
    slow = ChaosSink(sleep=0.05)
    rt = DeliveryRuntime()
    lane = rt.add_sink(slow, SinkPolicy.skip_batch(queue_depth=32))
    _submit_all(rt, 20)
    t0 = time.perf_counter()
    rt.close(drain=False, timeout=5.0)
    assert time.perf_counter() - t0 < 0.05 * 10  # did not write all 20
    m = lane.metrics
    assert m.discarded > 0
    assert m.delivered + m.discarded == 20       # accounted, just not written


# -- StreamingContext-level wiring --------------------------------------------

def test_streaming_context_policy_sink_rides_a_lane():
    broker = Broker()
    sc = StreamingContext(Context(), broker, max_records_per_partition=4)
    sc.subscribe_source(SyntheticRateSource(rate=1e9, total=12), topic="t")
    sc.foreach_batch(lambda rdd, info: rdd.count())
    seen = []
    sc.add_sink(seen.append, policy=SinkPolicy.skip_batch(), name="probe")
    while not (sc.sources_exhausted and sc.lag("t") == 0):
        sc.run_one_batch()
    sc.close(drain=True)
    assert [i.index for i in seen] == [b.index for b in sc.history]
    assert sc.delivery.report()["probe"]["delivered"] == len(sc.history)


# -- report() counter semantics -----------------------------------------------

def test_report_counter_semantics_under_concurrent_lanes():
    """Three lanes running concurrently — healthy, slow, crash-then-heal —
    report() returns exact per-lane counters, and the registry's
    ``delivery_*`` instruments agree with them (one fact, two surfaces)."""
    from repro.data.metrics import MetricsRegistry, set_registry
    reg = MetricsRegistry()
    prev = set_registry(reg)        # lanes cache instruments at construction
    try:
        runtime = DeliveryRuntime()
        ok, slow = ChaosSink(), ChaosSink(sleep=0.02)
        # fails calls 1-3: batch 0 burns both attempts (terminal failure),
        # batch 1 fails once then heals on its retry, batches 2-5 are clean
        flaky = ChaosSink(fail_first=3)
        runtime.add_sink(ok, SinkPolicy(), name="ok")
        runtime.add_sink(slow, SinkPolicy(), name="slow")
        runtime.add_sink(flaky, SinkPolicy(retries=1), name="flaky")
        _submit_all(runtime, 6)
        assert runtime.drain(timeout=30)
        rep = runtime.report()

        assert rep["ok"]["enqueued"] == 6 and rep["ok"]["delivered"] == 6
        assert rep["ok"]["failed"] == 0 and rep["ok"]["retries"] == 0
        assert rep["slow"]["delivered"] == 6
        assert rep["slow"]["mean_write_s"] >= 0.02
        assert rep["flaky"]["enqueued"] == 6
        assert rep["flaky"]["delivered"] == 5    # batch 1 healed on retry
        assert rep["flaky"]["failed"] == 1       # batch 0 exhausted retries
        assert rep["flaky"]["retries"] == 2      # one re-attempt per failure
        assert "chaos" in rep["flaky"]["last_error"]
        for lane in rep.values():
            assert lane["depth"] == 0            # drained
            assert lane["dropped_full"] == 0 and lane["dead_lettered"] == 0
        assert rep["ok"]["max_latency_s"] >= rep["ok"]["mean_latency_s"] > 0

        # the registry counters carry the same numbers
        for lane, field, want in (("ok", "delivered", 6),
                                  ("slow", "delivered", 6),
                                  ("flaky", "delivered", 5),
                                  ("flaky", "failed", 1),
                                  ("flaky", "retries", 2),
                                  ("flaky", "enqueued", 6)):
            c = reg.counter(f"delivery_{field}_total", labels={"lane": lane})
            assert c.value() == want, (lane, field)
        runtime.close()
    finally:
        set_registry(prev)


def test_serial_sinks_unaffected_by_delivery_runtime():
    """No policy => the degenerate serial path: no lanes, no threads."""
    before = threading.active_count()
    pipe = _pipeline(Broker(), 8, [ChaosSink()])
    pipe.run_until_drained()
    assert pipe.delivery_report() == {}
    assert threading.active_count() == before
    pipe.close()                                 # harmless no-op
