"""RDD middleware: transforms, lineage fault tolerance, stragglers."""
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container has no hypothesis; smoke path below
    HAVE_HYPOTHESIS = False

from repro.core import Context, FailureInjector, PartitionLostError
from repro.core.rdd import TaskScheduler


def test_map_filter_collect():
    ctx = Context()
    rdd = ctx.parallelize(range(100), 7)
    assert rdd.map(lambda x: x * 2).collect() == [2 * x for x in range(100)]
    assert rdd.filter(lambda x: x % 3 == 0).collect() == \
        [x for x in range(100) if x % 3 == 0]
    assert rdd.count() == 100


def test_union_preserves_partitions():
    ctx = Context()
    a = ctx.parallelize(range(10), 2)
    b = ctx.parallelize(range(10, 30), 3)
    u = a.union(b)
    assert u.num_partitions == 5
    assert sorted(u.collect()) == list(range(30))


def test_repartition_is_wide():
    ctx = Context()
    rdd = ctx.parallelize(range(20), 4).repartition(3)
    assert rdd.num_partitions == 3
    assert sorted(rdd.collect()) == list(range(20))
    assert len(rdd.lineage()) == 2


def test_zip_partitions():
    ctx = Context()
    a = ctx.from_partitions([np.arange(3), np.arange(3, 6)])
    b = ctx.from_partitions([np.ones(3), np.ones(3)])
    z = a.zip_partitions(b, lambda x, y: x + y)
    got = z.collect_partitions()
    np.testing.assert_array_equal(got[0], [1, 2, 3])
    np.testing.assert_array_equal(got[1], [4, 5, 6])


def test_reduce():
    ctx = Context()
    assert ctx.parallelize(range(10), 3).reduce(lambda a, b: a + b) == 45


def _check_partitioning_preserves_data(data, nparts):
    """Any partitioning of any data collects back to the original list."""
    ctx = Context()
    rdd = ctx.parallelize(data, min(nparts, len(data)))
    assert rdd.collect() == data
    assert rdd.map(lambda x: x + 1).collect() == [x + 1 for x in data]


def test_partitioning_preserves_data_smoke():
    """Deterministic replicas of the hypothesis property (runs everywhere)."""
    rng = np.random.default_rng(3)
    for n, nparts in ((1, 1), (7, 3), (60, 8), (13, 8)):
        _check_partitioning_preserves_data(
            rng.integers(-100, 100, n).tolist(), nparts)


if HAVE_HYPOTHESIS:
    @given(st.lists(st.integers(-100, 100), min_size=1, max_size=60),
           st.integers(1, 8))
    @settings(max_examples=25, deadline=None)
    def test_property_partitioning_preserves_data(data, nparts):
        _check_partitioning_preserves_data(data, nparts)


def test_lineage_recompute_on_injected_failure():
    """A partition that fails twice is recomputed from lineage and the job
    still returns the right answer (the RDD resilience contract)."""
    inj = FailureInjector(fail={1: 2})
    ctx = Context(scheduler=TaskScheduler(num_executors=2, max_failures=4,
                                          failure_injector=inj))
    rdd = ctx.parallelize(range(30), 3).map(lambda x: x * x)
    assert rdd.collect() == [x * x for x in range(30)]
    assert ctx.scheduler.metrics["retries"] == 2


def test_unrecoverable_failure_raises():
    inj = FailureInjector(fail={0: 99})
    ctx = Context(scheduler=TaskScheduler(num_executors=2, max_failures=2,
                                          failure_injector=inj))
    with pytest.raises(RuntimeError, match="failed"):
        ctx.parallelize(range(4), 2).collect()


def test_cached_partition_loss_recomputes():
    ctx = Context()
    calls = []
    base = ctx.parallelize(range(10), 2)
    traced = base.map_partitions_with_index(
        lambda i, part: (calls.append(i), part)[1]).cache()
    traced.collect()
    assert sorted(calls) == [0, 1]
    traced.unpersist_partition(1)          # simulate node loss
    traced.collect()
    assert sorted(calls) == [0, 1, 1]      # only partition 1 recomputed


def test_speculative_execution_beats_straggler():
    inj = FailureInjector(slow={0: 1.2})
    sched = TaskScheduler(num_executors=4, speculation=True,
                          speculation_multiplier=3.0,
                          speculation_quantile=0.25,
                          failure_injector=inj)
    ctx = Context(scheduler=sched)
    t0 = time.monotonic()
    out = ctx.parallelize(range(40), 8).map(lambda x: x + 1).collect()
    dt = time.monotonic() - t0
    assert out == [x + 1 for x in range(40)]
    assert sched.metrics["speculative"] >= 1
    assert dt < 1.1     # the speculative copy finished before the straggler
