"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.art import ops as art_ops
from repro.kernels.art import ref as art_ref
from repro.kernels.flash_attention import kernel as fa_kernel
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.modulus import kernel as mod_kernel
from repro.kernels.modulus import ref as mod_ref
from repro.kernels.overlap import kernel as ov_kernel
from repro.kernels.overlap import ref as ov_ref
from repro.kernels.raar import kernel as raar_kernel
from repro.kernels.raar import ref as raar_ref


def _planes(key, shape, dtype=jnp.float32, n=1):
    keys = jax.random.split(key, n)
    return [jax.random.normal(k, shape, dtype) for k in keys]


# -- modulus -------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(4, 16, 16), (7, 32, 32), (16, 8, 24),
                                   (1, 64, 64)])
@pytest.mark.parametrize("fb", [2, 16])
def test_modulus_sweep(shape, fb):
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    re, im, mag = _planes(key, shape, n=3)
    mag = jnp.abs(mag)
    got = mod_kernel.modulus_project(re, im, mag, block_frames=fb,
                                     interpret=True)
    want = mod_ref.modulus_project_ref(re, im, mag)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-6, atol=1e-6)


def test_modulus_projection_property():
    """|π₁ψ| == measured magnitude (the modulus constraint, paper eq. 1)."""
    key = jax.random.PRNGKey(0)
    re, im, mag = _planes(key, (3, 16, 16), n=3)
    mag = jnp.abs(mag) + 0.1
    ore, oim = mod_kernel.modulus_project(re, im, mag, interpret=True)
    np.testing.assert_allclose(np.sqrt(np.asarray(ore)**2 + np.asarray(oim)**2),
                               np.asarray(mag), rtol=1e-4, atol=1e-4)


# -- raar ----------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(4, 16, 16), (5, 8, 40)])
@pytest.mark.parametrize("beta", [0.5, 0.75, 0.9])
def test_raar_sweep(shape, beta):
    key = jax.random.PRNGKey(1)
    planes = _planes(key, shape, n=8)
    got = raar_kernel.raar_combine(*planes, beta=beta, block_frames=3,
                                   interpret=True)
    want = raar_ref.raar_combine_ref(*planes, beta=beta)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-6, atol=1e-6)


# -- overlap -------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(4, 16, 16), (9, 24, 8)])
def test_overlap_sweep(shape):
    key = jax.random.PRNGKey(2)
    a_re, a_im, b_re, b_im = _planes(key, shape, n=4)
    got = ov_kernel.overlap_products(a_re, a_im, b_re, b_im, block_frames=4,
                                     interpret=True)
    want = ov_ref.overlap_products_ref(a_re, a_im, b_re, b_im)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-6, atol=1e-6)


def test_overlap_matches_complex_ref():
    key = jax.random.PRNGKey(3)
    a_re, a_im, b_re, b_im = _planes(key, (3, 8, 8), n=4)
    a = a_re + 1j * a_im
    b = b_re + 1j * b_im
    n_re, n_im, den = ov_kernel.overlap_products(a_re, a_im, b_re, b_im,
                                                 interpret=True)
    num_c, den_c = ov_ref.overlap_products_complex(a, b)
    np.testing.assert_allclose(np.asarray(n_re + 1j * n_im),
                               np.asarray(num_c), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(den), np.asarray(den_c),
                               rtol=1e-5, atol=1e-5)


# -- art ----------------------------------------------------------------------
@pytest.mark.parametrize("nrow,ncol", [(8, 16), (20, 12), (32, 64)])
@pytest.mark.parametrize("iters", [1, 3])
def test_art_sweep(nrow, ncol, iters):
    key = jax.random.PRNGKey(4)
    A = jax.random.normal(key, (nrow, ncol))
    f_true = jax.random.normal(jax.random.PRNGKey(5), (ncol,))
    b = A @ f_true
    rip = jnp.sum(A * A, axis=1)
    inv_rip = 1.0 / rip
    f0 = jnp.zeros((ncol,))
    from repro.kernels.art import kernel as art_kernel
    got = art_kernel.art_sweep(A, b, inv_rip, f0, beta=1.0, iters=iters,
                               interpret=True)
    want = art_ref.art_sweep_ref(A, b, inv_rip, f0, beta=1.0, iters=iters)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_art_converges_consistent_system():
    """Kaczmarz converges on a consistent overdetermined system."""
    key = jax.random.PRNGKey(6)
    A = jax.random.normal(key, (64, 16))
    f_true = jax.random.normal(jax.random.PRNGKey(7), (16,))
    b = A @ f_true
    f = art_ops.art_reconstruct_slice(A, b, jnp.zeros((16,)), beta=1.0,
                                      iters=30, use_pallas=True)
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_true),
                               rtol=1e-3, atol=1e-3)


# -- flash attention ------------------------------------------------------------
@pytest.mark.parametrize("S,hd,bq,bkv", [(64, 16, 16, 32), (128, 32, 32, 32),
                                         (32, 8, 32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, hd, bq, bkv, dtype):
    key = jax.random.PRNGKey(8)
    BH = 4
    q = jax.random.normal(key, (BH, S, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(9), (BH, S, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(10), (BH, S, hd), dtype)
    got = fa_kernel.flash_attention_bhsd(q, k, v, block_q=bq, block_kv=bkv,
                                         causal=True, interpret=True)
    want = fa_ref.attention_ref(q, k, v, causal=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_model_layout():
    """ops wrapper: (B, S, H, hd) layout, padding path."""
    key = jax.random.PRNGKey(11)
    B, S, H, hd = 2, 40, 4, 16       # S=40 not divisible by blocks -> pad
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(12), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(13), (B, S, H, hd))
    got = fa_ops.flash_attention(q, k, v, block_q=16, block_kv=16,
                                 use_pallas=True)
    from repro.models.attention import naive_attention
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    want = naive_attention(q, k, v, pos, pos, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-5, atol=2e-5)
