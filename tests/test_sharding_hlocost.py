"""Sharding rules + the trip-count-aware HLO cost walker (1-device parts;
multi-device collective accounting lives in test_multidevice.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlocost import hlo_cost, parse_hlo, shape_bytes
from repro.parallel.sharding import (DEFAULT_RULES, ShardingRules,
                                     drop_indivisible, logical_constraint,
                                     use_mesh)


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_rules_spec_basic():
    rules = ShardingRules()
    mesh = FakeMesh({"data": 4, "model": 2})
    assert rules.spec(("batch", "seq", "embed"), mesh) == P("data")
    assert rules.spec(("vocab", "embed"), mesh) == P("model")
    assert rules.spec(("experts", "expert_cap", "embed"), mesh) == \
        P("model", "data")


def test_rules_pod_axis_dropped_on_single_pod():
    rules = ShardingRules()
    single = FakeMesh({"data": 16, "model": 16})
    multi = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert rules.spec(("batch",), single) == P("data")
    assert rules.spec(("batch",), multi) == P(("pod", "data"))


def test_rules_no_double_assignment():
    rules = ShardingRules(overrides={"expert_in": "model"})
    mesh = FakeMesh({"data": 4, "model": 2})
    spec = rules.spec(("experts", "expert_in", "ff"), mesh)
    # 'model' must appear once only
    used = [a for part in spec if part for a in
            (part if isinstance(part, tuple) else (part,))]
    assert len(used) == len(set(used))


def test_drop_indivisible():
    mesh = FakeMesh({"data": 4, "model": 16})
    # build a real Mesh-like via jax for shape arithmetic
    spec = P("model", "data")
    out = drop_indivisible(spec, (56, 8), mesh)
    assert out == P(None, "data")
    out = drop_indivisible(P(("data", "model")), (32,), mesh)
    assert out == P(("data",)) or out == P("data")


def test_logical_constraint_noop_single_device():
    x = jnp.ones((4, 4))
    y = logical_constraint(x, "batch", "embed")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_shape_bytes_parses_tuples():
    assert shape_bytes("f32[2,3]{1,0}") == 24
    assert shape_bytes("(f32[2]{0}, bf16[4]{0}, pred[])") == 8 + 8 + 1
    assert shape_bytes("token[]") == 0


def test_walker_counts_scan_trip_and_fusion_flops():
    def g(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    c = jax.jit(g).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)).compile()
    cost = hlo_cost(c.as_text())
    np.testing.assert_allclose(cost["flops"], 7 * 2 * 64 ** 3, rtol=1e-6)
    assert cost["bytes"] > 7 * (3 * 64 * 64 * 4)   # >= operand traffic


def test_walker_nested_while():
    def h(x, ws):
        def outer(c, w):
            def inner(ci, wi):
                return ci @ wi, None
            return jax.lax.scan(
                inner, c, jnp.broadcast_to(w, (3, 32, 32)))[0], None
        return jax.lax.scan(outer, x, ws)[0]

    c = jax.jit(h).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)).compile()
    cost = hlo_cost(c.as_text())
    np.testing.assert_allclose(cost["flops"], 5 * 3 * 2 * 32 ** 3, rtol=1e-6)


def test_walker_parse_roundtrip_entry():
    c = jax.jit(lambda x: x * 2 + 1).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
    comps, entry = parse_hlo(c.as_text())
    assert entry in comps
    assert comps[entry].instructions
