"""PMI KVS semantics: put/fence/get, generations, watchdog."""
import threading
import time

import pytest

from repro.core import KeyValueSpace, PMIClient, PMIServer, Watchdog
from repro.core.pmi import PMIError


def test_kvs_get_before_fence_raises():
    kvs = KeyValueSpace()
    kvs.put(0, "addr/0", "a:1")
    with pytest.raises(PMIError):
        kvs.get("addr/0")
    kvs.commit_all()
    assert kvs.get("addr/0") == "a:1"


def test_threaded_wireup_fence():
    """The paper's rank wire-up: every worker puts its endpoint, fences,
    then reads every other endpoint — race-free by the fence contract."""
    server = PMIServer(world_size=4)
    clients = [PMIClient(server, f"w{i}") for i in range(4)]
    results: dict[int, list[str]] = {}

    def worker(c: PMIClient):
        c.put(f"addr/{c.rank}", f"host{c.rank}:94{c.rank}0")
        c.fence(timeout=5)
        results[c.rank] = [c.get(f"addr/{r}") for r in range(4)]

    threads = [threading.Thread(target=worker, args=(c,)) for c in clients]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    assert len(results) == 4
    for r in range(4):
        assert results[r] == [f"host{i}:94{i}0" for i in range(4)]


def test_generation_bump_on_failure():
    server = PMIServer(world_size=3)
    clients = [PMIClient(server, f"w{i}") for i in range(3)]
    assert [c.rank for c in clients] == [0, 1, 2]
    gen = server.fail_worker("w1")
    assert gen == 1
    alive = server.alive_workers()
    assert [w.worker_id for w in alive] == ["w0", "w2"]
    assert [w.rank for w in alive] == [0, 1]       # dense re-rank


def test_watchdog_detects_stale_heartbeat():
    server = PMIServer(world_size=2, heartbeat_timeout=0.2)
    PMIClient(server, "w0")
    PMIClient(server, "w1")
    failures: list[list[str]] = []
    dog = Watchdog(server, interval=0.05, on_failure=failures.append)
    dog.start()
    t_end = time.monotonic() + 1.0
    while time.monotonic() < t_end and not failures:
        server.heartbeat("w0")      # only w0 stays alive
        time.sleep(0.05)
    dog.stop()
    assert failures and failures[0] == ["w1"]
    assert server.generation == 1
