"""Broker (Kafka semantics) + discretized streams."""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container has no hypothesis; smoke path below
    HAVE_HYPOTHESIS = False

from repro.core import Broker, Context, OffsetRange, StreamingContext, create_rdd


def test_partition_order_and_offsets():
    b = Broker()
    b.create_topic("t", 2)
    for i in range(10):
        b.produce("t", i, partition=i % 2)
    recs = b.read(OffsetRange("t", 0, 0, 5))
    assert [r.value for r in recs] == [0, 2, 4, 6, 8]
    assert [r.offset for r in recs] == list(range(5))
    assert b.end_offsets("t") == [5, 5]


def test_offset_range_reads_are_replayable():
    b = Broker()
    b.create_topic("t", 1)
    for i in range(8):
        b.produce("t", i)
    ctx = Context()
    r1 = create_rdd(ctx, b, [OffsetRange("t", 0, 2, 6)])
    r2 = create_rdd(ctx, b, [OffsetRange("t", 0, 2, 6)])
    assert r1.collect() == r2.collect() == [2, 3, 4, 5]


def _check_per_partition_total_order(partition_choices):
    """However producers interleave, each partition's log preserves produce
    order (Kafka's ordering contract: total per-partition, none across)."""
    b = Broker()
    b.create_topic("t", 4)
    expect: dict[int, list[int]] = {p: [] for p in range(4)}
    for i, p in enumerate(partition_choices):
        b.produce("t", i, partition=p)
        expect[p].append(i)
    for p in range(4):
        got = [r.value for r in b.read(OffsetRange("t", p, 0, 10 ** 6))]
        assert got == expect[p]


def test_per_partition_total_order_smoke():
    """Deterministic replicas of the hypothesis property (runs everywhere)."""
    rng = np.random.default_rng(7)
    for n in (1, 5, 80):
        _check_per_partition_total_order(rng.integers(0, 4, n).tolist())


if HAVE_HYPOTHESIS:
    @given(st.lists(st.integers(0, 3), min_size=1, max_size=80))
    @settings(max_examples=25, deadline=None)
    def test_property_per_partition_total_order(partition_choices):
        _check_per_partition_total_order(partition_choices)


def test_microbatch_union_across_topics():
    b = Broker()
    b.create_topic("a", 1)
    b.create_topic("b", 2)
    for i in range(6):
        b.produce("a", ("a", i))
        b.produce("b", ("b", i), partition=i % 2)
    ctx = Context()
    sc = StreamingContext(ctx, b)
    sc.subscribe(["a", "b"])
    seen = []
    sc.foreach_batch(lambda rdd, info: seen.extend(rdd.collect()))
    info = sc.run_one_batch()
    assert info.num_records == 12
    assert sorted(x[1] for x in seen if x[0] == "a") == list(range(6))
    assert sc.run_one_batch() is None      # drained


def test_offset_checkpoint_resume(tmp_path):
    """Restarted stream resumes exactly after the last committed batch."""
    b = Broker()
    b.create_topic("t", 1)
    for i in range(10):
        b.produce("t", i)
    path = str(tmp_path / "progress.json")
    ctx = Context()
    sc = StreamingContext(ctx, b, max_records_per_partition=4,
                          checkpoint_path=path)
    sc.subscribe(["t"])
    got = []
    sc.foreach_batch(lambda rdd, info: got.extend(rdd.collect()))
    sc.run_one_batch()
    assert got == [0, 1, 2, 3]
    # "crash" -> new context from the same checkpoint
    sc2 = StreamingContext(ctx, b, max_records_per_partition=4,
                           checkpoint_path=path)
    sc2.subscribe(["t"])
    got2 = []
    sc2.foreach_batch(lambda rdd, info: got2.extend(rdd.collect()))
    sc2.run_one_batch()
    sc2.run_one_batch()
    assert got2 == [4, 5, 6, 7, 8, 9]


def test_failed_batch_does_not_commit(tmp_path):
    b = Broker()
    b.create_topic("t", 1)
    for i in range(4):
        b.produce("t", i)
    ctx = Context()
    sc = StreamingContext(ctx, b, checkpoint_path=str(tmp_path / "p.json"))
    sc.subscribe(["t"])
    calls = {"n": 0}

    def flaky(rdd, info):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient sink failure")
        return rdd.collect()

    sc.foreach_batch(flaky)
    with pytest.raises(RuntimeError):
        sc.run_one_batch()
    info = sc.run_one_batch()              # replays the same records
    assert info.result == [0, 1, 2, 3]     # at-least-once delivery


def test_realtime_report():
    b = Broker()
    b.create_topic("t", 1)
    for i in range(20):
        b.produce("t", i)
    ctx = Context()
    sc = StreamingContext(ctx, b, batch_interval=5.0,
                          max_records_per_partition=5)
    sc.subscribe(["t"])
    sc.foreach_batch(lambda rdd, info: rdd.count())
    sc.run_batches(4)
    rep = sc.realtime_report()
    assert rep["batches"] == 4 and rep["records"] == 20
    assert rep["keeps_up"] is True
