"""Broker (Kafka semantics) + discretized streams."""
import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container has no hypothesis; smoke path below
    HAVE_HYPOTHESIS = False

from repro.core import (Broker, Context, OffsetRange, StreamingContext,
                        StreamProgress, create_rdd)


def test_partition_order_and_offsets():
    b = Broker()
    b.create_topic("t", 2)
    for i in range(10):
        b.produce("t", i, partition=i % 2)
    recs = b.read(OffsetRange("t", 0, 0, 5))
    assert [r.value for r in recs] == [0, 2, 4, 6, 8]
    assert [r.offset for r in recs] == list(range(5))
    assert b.end_offsets("t") == [5, 5]


def test_offset_range_reads_are_replayable():
    b = Broker()
    b.create_topic("t", 1)
    for i in range(8):
        b.produce("t", i)
    ctx = Context()
    r1 = create_rdd(ctx, b, [OffsetRange("t", 0, 2, 6)])
    r2 = create_rdd(ctx, b, [OffsetRange("t", 0, 2, 6)])
    assert r1.collect() == r2.collect() == [2, 3, 4, 5]


def _check_per_partition_total_order(partition_choices):
    """However producers interleave, each partition's log preserves produce
    order (Kafka's ordering contract: total per-partition, none across)."""
    b = Broker()
    b.create_topic("t", 4)
    expect: dict[int, list[int]] = {p: [] for p in range(4)}
    for i, p in enumerate(partition_choices):
        b.produce("t", i, partition=p)
        expect[p].append(i)
    for p in range(4):
        got = [r.value for r in b.read(OffsetRange("t", p, 0, 10 ** 6))]
        assert got == expect[p]


def test_per_partition_total_order_smoke():
    """Deterministic replicas of the hypothesis property (runs everywhere)."""
    rng = np.random.default_rng(7)
    for n in (1, 5, 80):
        _check_per_partition_total_order(rng.integers(0, 4, n).tolist())


if HAVE_HYPOTHESIS:
    @given(st.lists(st.integers(0, 3), min_size=1, max_size=80))
    @settings(max_examples=25, deadline=None)
    def test_property_per_partition_total_order(partition_choices):
        _check_per_partition_total_order(partition_choices)


def test_microbatch_union_across_topics():
    b = Broker()
    b.create_topic("a", 1)
    b.create_topic("b", 2)
    for i in range(6):
        b.produce("a", ("a", i))
        b.produce("b", ("b", i), partition=i % 2)
    ctx = Context()
    sc = StreamingContext(ctx, b)
    sc.subscribe(["a", "b"])
    seen = []
    sc.foreach_batch(lambda rdd, info: seen.extend(rdd.collect()))
    info = sc.run_one_batch()
    assert info.num_records == 12
    assert sorted(x[1] for x in seen if x[0] == "a") == list(range(6))
    assert sc.run_one_batch() is None      # drained


def test_offset_checkpoint_resume(tmp_path):
    """Restarted stream resumes exactly after the last committed batch."""
    b = Broker()
    b.create_topic("t", 1)
    for i in range(10):
        b.produce("t", i)
    path = str(tmp_path / "progress.json")
    ctx = Context()
    sc = StreamingContext(ctx, b, max_records_per_partition=4,
                          checkpoint_path=path)
    sc.subscribe(["t"])
    got = []
    sc.foreach_batch(lambda rdd, info: got.extend(rdd.collect()))
    sc.run_one_batch()
    assert got == [0, 1, 2, 3]
    # "crash" -> new context from the same checkpoint
    sc2 = StreamingContext(ctx, b, max_records_per_partition=4,
                           checkpoint_path=path)
    sc2.subscribe(["t"])
    got2 = []
    sc2.foreach_batch(lambda rdd, info: got2.extend(rdd.collect()))
    sc2.run_one_batch()
    sc2.run_one_batch()
    assert got2 == [4, 5, 6, 7, 8, 9]


def test_failed_batch_does_not_commit(tmp_path):
    b = Broker()
    b.create_topic("t", 1)
    for i in range(4):
        b.produce("t", i)
    ctx = Context()
    sc = StreamingContext(ctx, b, checkpoint_path=str(tmp_path / "p.json"))
    sc.subscribe(["t"])
    calls = {"n": 0}

    def flaky(rdd, info):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient sink failure")
        return rdd.collect()

    sc.foreach_batch(flaky)
    with pytest.raises(RuntimeError):
        sc.run_one_batch()
    info = sc.run_one_batch()              # replays the same records
    assert info.result == [0, 1, 2, 3]     # at-least-once delivery


def test_pump_round_robin_persists_across_batches():
    """The produce cursor must survive the batch loop: resetting it every
    pump landed *every* record on partition 0 whenever a poll returned fewer
    records than the topic has partitions (e.g. poll_batch=1, 4 partitions).
    """
    from repro.data import SyntheticRateSource

    b = Broker()
    sc = StreamingContext(Context(), b)
    sc.subscribe_source(SyntheticRateSource(rate=1e9, total=16), topic="t",
                        partitions=4, poll_batch=1)
    sc.foreach_batch(lambda rdd, info: rdd.count())
    while not (sc.sources_exhausted and sc.lag("t") == 0):
        sc.run_one_batch()
    assert b.end_offsets("t") == [4, 4, 4, 4]   # near-even, not all-on-p0


def test_checkpoint_from_fewer_partitions_consumes_new_ones(tmp_path):
    """A checkpoint written when the topic had 2 partitions, replayed
    against a 4-partition topic: the padded offsets must consume the new
    partitions from 0 instead of silently never reading them."""
    path = str(tmp_path / "progress.json")
    b2 = Broker()
    b2.create_topic("t", 2)
    for i in range(6):
        b2.produce("t", i, partition=i % 2)
    sc = StreamingContext(Context(), b2, checkpoint_path=path)
    sc.subscribe(["t"])
    sc.foreach_batch(lambda rdd, info: rdd.count())
    sc.run_one_batch()
    assert StreamProgress.load(path).offsets["t"] == [3, 3]

    b4 = Broker()                          # the repartitioned topic
    b4.create_topic("t", 4)
    for i in range(12):
        b4.produce("t", i, partition=i % 4)
    sc2 = StreamingContext(Context(), b4, checkpoint_path=path)
    sc2.subscribe(["t"])
    seen = []
    sc2.foreach_batch(lambda rdd, info: seen.extend(rdd.collect()))
    info = sc2.run_one_batch()
    # partitions 0/1 resume at 3; partitions 2/3 are consumed from 0
    assert [(r.partition, r.start, r.until) for r in info.ranges] == \
        [(2, 0, 3), (3, 0, 3)]
    assert sorted(seen) == [2, 3, 6, 7, 10, 11]
    assert StreamProgress.load(path).offsets["t"] == [3, 3, 3, 3]


def test_partition_growth_between_batches_is_picked_up():
    """Padding re-runs every batch, so partitions added after subscribe are
    consumed too (not only ones present at subscribe time)."""
    b = Broker()
    b.create_topic("t", 1)
    b.produce("t", 0)
    sc = StreamingContext(Context(), b)
    sc.subscribe(["t"])
    seen = []
    sc.foreach_batch(lambda rdd, info: seen.extend(rdd.collect()))
    sc.run_one_batch()
    b._topics["t"].append(type(b._topics["t"][0])())   # grow the topic
    for done in b._committed["t"].values():            # pad every group
        done.append(0)
    b.produce("t", 1, partition=1)
    sc.run_one_batch()
    assert sorted(seen) == [0, 1]


def test_serial_sink_runs_before_commit(tmp_path):
    """A crash between commit and sink delivery used to lose the batch from
    every serial sink. Sinks now run before the commit: a raising sink
    leaves offsets and checkpoint untouched and the batch replays."""
    path = str(tmp_path / "p.json")
    b = Broker()
    b.create_topic("t", 1)
    for i in range(4):
        b.produce("t", i)
    sc = StreamingContext(Context(), b, checkpoint_path=path)
    sc.subscribe(["t"])
    sc.foreach_batch(lambda rdd, info: rdd.collect())
    events = []
    sc.add_sink(lambda info: events.append(("sink", list(info.result))))

    armed = {"boom": True}

    def exploding(info):
        events.append(("boom", list(info.result)))
        if armed.pop("boom", False):
            raise RuntimeError("sink died")

    sc.add_sink(exploding)
    with pytest.raises(RuntimeError):
        sc.run_one_batch()
    # nothing committed anywhere: memory, checkpoint file, broker-side
    assert sc.committed("t") == 0
    assert StreamProgress.load(path).offsets == {}
    assert b.committed("t") == [0]
    assert sc.history == []                # the batch did not count
    info = sc.run_one_batch()              # replay delivers to every sink
    assert info.result == [0, 1, 2, 3]
    assert events == [("sink", [0, 1, 2, 3]), ("boom", [0, 1, 2, 3]),
                      ("sink", [0, 1, 2, 3]), ("boom", [0, 1, 2, 3])]


def test_corrupt_checkpoint_degrades_to_empty(tmp_path, caplog):
    """A torn or garbage checkpoint must not make the restart unrecoverable:
    load falls back to empty progress (replay from 0) with a warning."""
    path = str(tmp_path / "p.json")
    full = StreamProgress(offsets={"t": [5]}, epoch=3)
    full.save(path)
    blob = open(path, "rb").read()
    cases = {
        "truncated": blob[:len(blob) // 2],
        "garbage": b"\x00\xffnot json at all",
        "wrong-shape": b'{"offsets": 42}',
        "missing-key": b'{"epoch": 1}',
    }
    for name, payload in cases.items():
        with open(path, "wb") as f:
            f.write(payload)
        got = StreamProgress.load(path)
        assert got.offsets == {} and got.epoch == 0, name
    # and the stream actually restarts from offset 0
    b = Broker()
    b.create_topic("t", 1)
    for i in range(3):
        b.produce("t", i)
    sc = StreamingContext(Context(), b, checkpoint_path=path)
    sc.subscribe(["t"])
    seen = []
    sc.foreach_batch(lambda rdd, info: seen.extend(rdd.collect()))
    sc.run_one_batch()
    assert seen == [0, 1, 2]


def test_old_format_checkpoint_still_loads(tmp_path):
    path = str(tmp_path / "p.json")
    with open(path, "w") as f:
        json.dump({"offsets": {"t": [7]}}, f)   # pre-epoch format
    got = StreamProgress.load(path)
    assert got.offsets == {"t": [7]} and got.epoch == 0
    assert got.window_refs == {}


def test_realtime_report():
    b = Broker()
    b.create_topic("t", 1)
    for i in range(20):
        b.produce("t", i)
    ctx = Context()
    sc = StreamingContext(ctx, b, batch_interval=5.0,
                          max_records_per_partition=5)
    sc.subscribe(["t"])
    sc.foreach_batch(lambda rdd, info: rdd.count())
    sc.run_batches(4)
    rep = sc.realtime_report()
    assert rep["batches"] == 4 and rep["records"] == 20
    assert rep["keeps_up"] is True
