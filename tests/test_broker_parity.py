"""Broker contract parity matrix.

Every test here runs against six interchangeable broker backends — the
in-process :class:`Broker`, :class:`RemoteBroker` over TCP and over a Unix
domain socket, a :class:`Broker` storing on disk through
``DurableLogFactory``, a replicated primary+follower pair behind
:class:`FailoverBroker`, and a :class:`CodecBroker` compressing every value
losslessly — pinning the duck type the rest of the system
(``IngestRunner``, ``StreamingContext``, ``TopicSource``) relies on:
identical results, identical error types, including ``produce_many``'s
all-or-nothing validation semantics.
"""
import numpy as np
import pytest

from repro.core import Broker, OffsetRange
from repro.data import RemoteBroker, serve_broker
from repro.data.codec import CodecBroker
from repro.data.durable_log import DurableLogFactory
from repro.data.replication import FailoverBroker, ReplicaFollower

BACKENDS = ("local", "durable", "uds", "tcp", "failover", "codec")


@pytest.fixture(params=BACKENDS)
def anybroker(request, tmp_path):
    if request.param == "local":
        yield Broker()
        return
    if request.param == "codec":
        # lossless zlib wrapper: encode on produce, decode on read must be
        # observationally invisible against the whole contract matrix
        yield CodecBroker(Broker(), codec="zlib")
        return
    if request.param == "durable":
        yield Broker(log_factory=DurableLogFactory(str(tmp_path / "wal")))
        return
    if request.param == "failover":
        # durable primary + live follower, all calls through the HA client:
        # replication and the resend window must be invisible to the duck
        # type (same results, same error types as every other backend)
        from repro.core.broker import COMMIT_TOPIC
        backing = Broker(log_factory=DurableLogFactory(str(tmp_path / "p")),
                         commit_topic=COMMIT_TOPIC)
        server = serve_broker(backing, str(tmp_path / "p.sock"))
        follower = ReplicaFollower(server.address, str(tmp_path / "f"),
                                   poll_interval=0.005)
        faddr = follower.serve(str(tmp_path / "f.sock"))
        follower.start()
        client = FailoverBroker([server.address, faddr])
        yield client
        client.close()
        follower.stop()
        server.stop()
        return
    backing = Broker()
    address = (str(tmp_path / "b.sock") if request.param == "uds"
               else ("127.0.0.1", 0))
    server = serve_broker(backing, address)
    client = RemoteBroker(server.address, max_retries=2, retry_delay=0.01)
    yield client
    client.close()
    server.stop()


def test_topic_lifecycle(anybroker):
    anybroker.create_topic("a", 2)
    anybroker.create_topic("b")
    assert anybroker.topics() == ["a", "b"]
    assert anybroker.num_partitions("a") == 2
    assert anybroker.num_partitions("b") == 1
    with pytest.raises(ValueError):
        anybroker.create_topic("a")        # duplicate
    with pytest.raises(KeyError):
        anybroker.end_offsets("missing")   # unknown


def test_produce_read_roundtrip(anybroker):
    anybroker.create_topic("t", 2)
    for i in range(8):
        assert anybroker.produce("t", {"i": i}, key=f"k{i}".encode(),
                                 partition=i % 2) == i // 2
    assert anybroker.end_offsets("t") == [4, 4]
    recs = anybroker.read(OffsetRange("t", 1, 1, 3))
    assert [r.value for r in recs] == [{"i": 3}, {"i": 5}]
    assert [r.offset for r in recs] == [1, 2]
    assert [r.key for r in recs] == [b"k3", b"k5"]


def test_produce_many_offsets_and_order(anybroker):
    anybroker.create_topic("t", 2)
    offs = anybroker.produce_many(
        "t", [(f"k{i}".encode(), i) for i in range(5)], partition=1)
    assert offs == [0, 1, 2, 3, 4]
    # a second batch continues the offset space
    assert anybroker.produce_many("t", [(None, 5), (None, 6)],
                                  partition=1) == [5, 6]
    got = anybroker.read(OffsetRange("t", 1, 0, 100))
    assert [r.value for r in got] == list(range(7))
    assert [r.offset for r in got] == list(range(7))
    assert anybroker.end_offsets("t") == [0, 7]
    assert anybroker.produce_many("t", []) == []


def test_produce_many_key_routing(anybroker):
    """partition=None routes per pair by a *stable* key hash (CRC-32, not
    Python's per-process-salted hash()): same key -> same partition, in any
    process, in any restart — which is what lets a durable log's replayed
    history and a restarted producer's new records meet on one partition.
    Relative per-key order is preserved."""
    import zlib

    anybroker.create_topic("t", 3)
    pairs = [(f"k{i % 4}".encode(), i) for i in range(24)]
    anybroker.produce_many("t", pairs)
    for key in (b"k0", b"k1", b"k2", b"k3"):
        expect = zlib.crc32(key) % 3
        recs = anybroker.read(OffsetRange("t", expect, 0, 100))
        assert any(r.key == key for r in recs)
    assert sum(anybroker.end_offsets("t")) == 24
    where = {}
    for p in range(3):
        recs = anybroker.read(OffsetRange("t", p, 0, 100))
        by_key = {}
        for r in recs:
            where.setdefault(r.key, set()).add(p)
            by_key.setdefault(r.key, []).append(r.value)
        for vals in by_key.values():
            assert vals == sorted(vals)    # per-key order preserved
    assert all(len(ps) == 1 for ps in where.values())


def test_produce_many_partial_failure_validation(anybroker):
    """Bad batches are all-or-nothing: validation failures append *nothing*,
    and the error type crosses the wire intact."""
    anybroker.create_topic("t", 2)
    anybroker.produce("t", "baseline", partition=0)
    with pytest.raises(KeyError):
        anybroker.produce_many("nope", [(None, 1)])
    for bad_partition in (-1, 2, 99):
        with pytest.raises(ValueError):
            anybroker.produce_many("t", [(None, 1)], partition=bad_partition)
    with pytest.raises(ValueError):        # malformed pair mid-batch...
        anybroker.produce_many("t", [(None, 1), (None, 2, 3)], partition=0)
    with pytest.raises(ValueError):
        anybroker.produce_many("t", [(None, 1), 7], partition=0)
    with pytest.raises(ValueError):        # unroutable key with partition=None
        anybroker.produce_many("t", [(b"good", 1), ([1, 2], 2)])
    # ...appended nothing, not a prefix
    assert anybroker.end_offsets("t") == [1, 0]
    assert [r.value for r in anybroker.read(OffsetRange("t", 0, 0, 10))] == \
        ["baseline"]


def test_commit_monotonic_and_lag(anybroker):
    anybroker.create_topic("t", 2)
    anybroker.produce_many("t", [(None, i) for i in range(6)], partition=0)
    anybroker.produce_many("t", [(None, i) for i in range(4)], partition=1)
    assert anybroker.lag("t") == 10
    anybroker.commit("t", 0, 5)
    anybroker.commit("t", 0, 2)            # replay never rewinds progress
    anybroker.commit("t", 1, 4)
    assert anybroker.committed("t") == [5, 4]
    assert anybroker.lag("t") == 1
    with pytest.raises(ValueError):
        anybroker.commit("t", 0, 99)       # past the end
    with pytest.raises(ValueError):
        anybroker.commit("t", -1, 0)       # negative-index partition
    assert anybroker.committed("t") == [5, 4]


def test_per_group_commit_isolation(anybroker):
    """Consumer groups commit and lag independently: two groups walk the
    same topic at their own pace, neither touches the default group's
    offsets, and the group enumeration crosses every backend intact."""
    anybroker.create_topic("t", 2)
    anybroker.produce_many("t", [(None, i) for i in range(6)], partition=0)
    anybroker.produce_many("t", [(None, i) for i in range(4)], partition=1)
    anybroker.commit("t", 0, 5, group="g1")
    anybroker.commit("t", 1, 2, group="g2")
    assert anybroker.committed("t", group="g1") == [5, 0]
    assert anybroker.committed("t", group="g2") == [0, 2]
    assert anybroker.committed("t") == [0, 0]      # default group untouched
    assert anybroker.lag("t", group="g1") == 5
    assert anybroker.lag("t", group="g2") == 8
    assert anybroker.lag("t") == 10
    assert sorted(anybroker.commit_groups("t")) == ["", "g1", "g2"]
    anybroker.commit("t", 0, 3, group="g1")        # replay never rewinds
    assert anybroker.committed("t", group="g1") == [5, 0]
    with pytest.raises(ValueError):
        anybroker.commit("t", 0, 99, group="g1")   # past the end


def test_numpy_payloads_roundtrip_writable(anybroker):
    """Detector-style records: ndarray values survive every backend (array
    frames over the socket, raw segment bytes on disk) and come back
    writable and equal."""
    anybroker.create_topic("frames")
    arrs = [np.arange(i, i + 12, dtype=np.float32).reshape(3, 4)
            for i in range(3)]
    anybroker.produce_many("frames", [(f"f{i}".encode(), (i, a))
                                      for i, a in enumerate(arrs)],
                           partition=0)
    recs = anybroker.read(OffsetRange("frames", 0, 0, 10))
    assert len(recs) == 3
    for i, rec in enumerate(recs):
        idx, got = rec.value
        assert idx == i and got.dtype == np.float32
        np.testing.assert_array_equal(got, arrs[i])
        assert got.flags.writeable
        got += 1.0                         # must not raise
