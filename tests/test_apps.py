"""Application-level behaviour: ptycho RAAR convergence, tomo ART, and the
streaming pipelines end-to-end (paper §III/§IV)."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.ptycho.sim import (gather_patches, scatter_add_patches,
                                   simulate)
from repro.apps.ptycho.solver import (SolverConfig, overlap_update,
                                      raar_step, reconstruct,
                                      reconstruction_quality, init_waves)
from repro.apps.tomo.solver import (TomoConfig, reconstruct_slices, residual,
                                    simulate_tilt_series)
from repro.core import (Broker, Context, NearRealTimePipeline,
                        PipelineConfig)


def test_gather_scatter_adjoint():
    """<scatter(x), y> == <x, gather(y)> — the adjoint pair used by eqs 4-5."""
    key = jax.random.PRNGKey(0)
    obj = jax.random.normal(key, (16, 16))
    pos = np.array([[0, 0], [4, 7], [9, 9]], np.int32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 6, 6))
    scat = scatter_add_patches(jnp.zeros((16, 16)), pos, x)
    gath = gather_patches(obj, pos, 6)
    lhs = float(jnp.sum(scat * obj))
    rhs = float(jnp.sum(x * gath))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5)


def test_overlap_update_recovers_object_from_true_waves():
    """Given the TRUE exit waves, eq. (4) recovers the object on the scanned
    region (up to probe coverage)."""
    prob = simulate(obj_size=64, probe_size=24, step=6)
    patches = gather_patches(prob.object_true, jnp.asarray(prob.positions),
                             24)
    psi_true = prob.probe_true[None] * patches
    obj, _ = overlap_update(psi_true, jnp.asarray(prob.positions),
                            prob.probe_true, (64, 64), update_probe=False,
                            use_pallas=False)
    m = 16
    got = np.asarray(obj)[m:-m, m:-m]
    want = np.asarray(prob.object_true)[m:-m, m:-m]
    np.testing.assert_allclose(np.abs(got), np.abs(want), rtol=0.1, atol=0.1)


def test_raar_reconstruction_converges():
    prob = simulate(obj_size=96, probe_size=32, step=8)
    cfg = SolverConfig(iterations=50, use_pallas=False)
    out = reconstruct(prob, cfg)
    errs = np.asarray(out["errors"])
    assert errs[-1] < 0.35 * errs[0]
    q = reconstruction_quality(out["object"], prob.object_true, margin=16)
    assert q > 0.9, q


def test_raar_with_pallas_kernels_matches_ref_path():
    """One RAAR step with Pallas kernels (interpret) == pure-jnp path."""
    prob = simulate(obj_size=48, probe_size=16, step=6)
    pos = jnp.asarray(prob.positions)
    cfg_ref = SolverConfig(use_pallas=False)
    cfg_pl = SolverConfig(use_pallas=True)
    psi = init_waves(prob.magnitudes, prob.probe_true)
    a = raar_step(psi, prob.magnitudes, pos, prob.probe_true, (48, 48),
                  cfg_ref, 5)
    b = raar_step(psi, prob.magnitudes, pos, prob.probe_true, (48, 48),
                  cfg_pl, 5)
    for x, y in zip(a[:3], b[:3]):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-4, atol=2e-4)


def test_tomo_art_reduces_residual():
    cfg = TomoConfig(nray=32, angles=tuple(np.linspace(-75, 75, 19).tolist()),
                     iterations=3, use_pallas=False)
    vol, sino = simulate_tilt_series(cfg, nslice=6)
    rec = reconstruct_slices(sino, cfg)
    r = residual(rec, sino, cfg)
    assert r < 0.3, r                      # limited-angle ART: large drop
    err = np.linalg.norm(rec - vol) / np.linalg.norm(vol)
    assert err < 0.6, err


def test_near_realtime_pipeline_end_to_end():
    """Producer thread -> broker -> micro-batches -> process -> report."""
    broker = Broker()
    broker.create_topic("frames", partitions=2)
    done = threading.Event()

    def producer():
        for i in range(40):
            broker.produce("frames", float(i), partition=i % 2)
        done.set()

    sums = []

    def process(rdd, info, bridge):
        vals = rdd.collect()
        sums.append(sum(vals))
        return sums[-1]

    pipe = NearRealTimePipeline(
        broker, PipelineConfig(topics=["frames"], batch_interval=0.02,
                               max_records_per_partition=5),
        process)
    threading.Thread(target=producer, daemon=True).start()
    report = pipe.run_until_drained(lambda: done.is_set())
    assert report.records == 40
    assert sum(sums) == sum(range(40))
    assert report.batches >= 4
    assert report.mean_latency < 0.5
