"""Metrics registry + trace-span unit tests: instrument semantics
(get-or-create identity, counter monotonicity, callback gauges, histogram
buckets), the two serialization surfaces (Prometheus text, JSON snapshot),
the ring-buffer time series, the NullRegistry off switch, and the
TraceLog/SpanRecorder batch-span machinery documented in
docs/observability.md.
"""
import math
import threading

import pytest

from repro.data.metrics import (COUNT_BUCKETS, DEFAULT_BUCKETS, BatchSpan,
                                Counter, Gauge, Histogram, MetricsRegistry,
                                NullRegistry, SPAN_STAGES, TraceLog, disabled,
                                get_registry, set_registry)


# -- registry identity --------------------------------------------------------

def test_get_or_create_returns_same_instrument():
    reg = MetricsRegistry()
    a = reg.counter("hits_total", "help once")
    b = reg.counter("hits_total", "ignored on re-register")
    assert a is b
    a.inc(3)
    assert b.value() == 3


def test_identity_is_name_plus_labels_order_insensitive():
    reg = MetricsRegistry()
    a = reg.counter("c", labels={"topic": "t", "part": "0"})
    b = reg.counter("c", labels={"part": "0", "topic": "t"})
    c = reg.counter("c", labels={"topic": "other"})
    assert a is b
    assert c is not a
    assert len(reg.metrics()) == 2


def test_kind_mismatch_is_an_error():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError, match="already registered as counter"):
        reg.gauge("x")


# -- instruments --------------------------------------------------------------

def test_counter_monotonic():
    c = MetricsRegistry().counter("n_total")
    c.inc()
    c.inc(4)
    assert c.value() == 5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_inc_dec():
    g = MetricsRegistry().gauge("depth")
    g.set(10)
    g.inc(2)
    g.dec(5)
    assert g.value() == 7


def test_callback_gauge_reads_live_and_latest_wins():
    reg = MetricsRegistry()
    box = {"v": 1}
    g = reg.gauge("live", callback=lambda: box["v"])
    box["v"] = 42
    assert g.value() == 42
    # a rebuilt component re-registers: its callback replaces the old one
    g2 = reg.gauge("live", callback=lambda: 7)
    assert g2 is g
    assert g.value() == 7


def test_dead_callback_gauge_is_nan_not_a_crash():
    g = MetricsRegistry().gauge(
        "dead", callback=lambda: (_ for _ in ()).throw(RuntimeError("gone")))
    assert math.isnan(g.value())
    # and serializes as null, never NaN, in the JSON snapshot
    reg = MetricsRegistry()
    reg.gauge("dead", callback=lambda: 1 / 0)
    (entry,) = reg.snapshot()["metrics"]
    assert entry["value"] is None


def test_histogram_buckets_sum_count():
    h = MetricsRegistry().histogram("lat_seconds", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.05, 0.5, 99.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["buckets"] == [0.01, 0.1, 1.0]
    assert snap["counts"] == [1, 3, 4, 5]      # cumulative, last is +Inf
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(99.605)
    assert h.value() == 5                      # scalar view = observations


def test_histogram_timer_context():
    h = MetricsRegistry().histogram("t_seconds")
    with h.time():
        pass
    snap = h.snapshot()
    assert snap["count"] == 1
    assert 0 <= snap["sum"] < 1.0


def test_count_buckets_cover_flush_sizes():
    h = MetricsRegistry().histogram("flush", buckets=COUNT_BUCKETS)
    h.observe(64)
    snap = h.snapshot()
    i = snap["buckets"].index(64)
    assert snap["counts"][i] == 1
    assert snap["counts"][i - 1] == 0


def test_counter_thread_safety():
    c = MetricsRegistry().counter("n")
    threads = [threading.Thread(target=lambda: [c.inc() for _ in range(1000)])
               for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value() == 8000


# -- serialization ------------------------------------------------------------

def test_prometheus_text_format():
    reg = MetricsRegistry(namespace="repro")
    reg.counter("reads_total", "records read",
                labels={"topic": "t"}).inc(3)
    reg.gauge("depth").set(2)
    reg.histogram("lat_seconds", buckets=(0.1, 1.0)).observe(0.05)
    text = reg.prometheus_text()
    assert "# HELP repro_reads_total records read" in text
    assert "# TYPE repro_reads_total counter" in text
    assert 'repro_reads_total{topic="t"} 3' in text
    assert "repro_depth 2" in text
    assert 'repro_lat_seconds_bucket{le="0.1"} 1' in text
    assert 'repro_lat_seconds_bucket{le="+Inf"} 1' in text
    assert "repro_lat_seconds_sum 0.05" in text
    assert "repro_lat_seconds_count 1" in text


def test_snapshot_shape():
    reg = MetricsRegistry()
    reg.counter("c", "h", labels={"a": "b"}).inc()
    reg.sample(now=1.0)
    snap = reg.snapshot()
    assert set(snap) == {"sampled_at", "metrics"}
    (m,) = snap["metrics"]
    assert m["name"] == "c" and m["kind"] == "counter"
    assert m["labels"] == {"a": "b"} and m["value"] == 1
    assert m["series"] == [(1.0, 1)]


def test_ring_buffer_series_is_bounded():
    reg = MetricsRegistry(ring_size=4)
    c = reg.counter("c")
    for i in range(10):
        c.inc()
        reg.sample(now=float(i))
    pts = c.series_points()
    assert len(pts) == 4                       # bounded by ring_size
    assert [t for t, _ in pts] == [6.0, 7.0, 8.0, 9.0]
    assert [v for _, v in pts] == [7, 8, 9, 10]


# -- the off switch -----------------------------------------------------------

def test_null_registry_absorbs_everything():
    reg = NullRegistry()
    c = reg.counter("c")
    c.inc()
    reg.gauge("g").set(5)
    h = reg.histogram("h")
    h.observe(1.0)
    with h.time():
        pass
    assert reg.metrics() == []
    assert reg.snapshot()["metrics"] == []
    assert reg.prometheus_text() == "\n"


def test_set_registry_returns_previous_and_disabled_restores():
    base = get_registry()
    mine = MetricsRegistry()
    prev = set_registry(mine)
    try:
        assert prev is base
        assert get_registry() is mine
        with disabled() as null:
            assert isinstance(null, NullRegistry)
            assert get_registry() is null
        assert get_registry() is mine          # restored on exit
    finally:
        set_registry(prev)
    assert get_registry() is base


# -- trace spans --------------------------------------------------------------

def test_span_stages_cover_the_documented_pipeline_order():
    assert SPAN_STAGES == ("pump", "batch_fn", "sinks", "state_commit",
                           "checkpoint", "broker_commit", "delivery_submit")


def test_span_recorder_builds_and_records_a_span():
    log = TraceLog()
    rec = log.begin(batch_index=3, num_records=17)
    rec.add("pump", 0.25)
    rec.add("pump", 0.25)                      # accumulates
    with rec.stage("batch_fn"):
        pass
    with rec.stage("batch_fn"):                # re-entry accumulates too
        pass
    span = rec.finish(epoch=9)
    assert span.batch_index == 3 and span.num_records == 17
    assert span.epoch == 9
    assert span.stages["pump"] == pytest.approx(0.5)
    assert span.stages["batch_fn"] >= 0
    assert span.total_s >= 0
    assert log.last() == [span]
    assert log.recorded == 1
    d = span.as_dict()
    assert set(d) == {"batch_index", "epoch", "num_records", "started_at",
                      "total_s", "stages"}


def test_trace_log_capacity_and_last_n():
    log = TraceLog(capacity=3)
    for i in range(5):
        log.begin(i, 1).finish(epoch=i + 1)
    spans = log.last()
    assert [s.batch_index for s in spans] == [2, 3, 4]   # oldest evicted
    assert log.recorded == 5                             # total, not retained
    assert [s.batch_index for s in log.last(2)] == [3, 4]
    assert log.last(0) == []


def test_stage_totals_roll_up_across_spans():
    log = TraceLog()
    for i in range(3):
        rec = log.begin(i, 1)
        rec.add("batch_fn", 0.1)
        rec.add("sinks", 0.01)
        rec.finish(epoch=i + 1)
    totals = log.stage_totals()
    assert totals["batch_fn"] == pytest.approx(0.3)
    assert totals["sinks"] == pytest.approx(0.03)


def test_unfinished_span_is_not_recorded():
    log = TraceLog()
    rec = log.begin(0, 4)
    rec.add("pump", 0.1)                       # abandoned: batch failed
    assert log.last() == []
    assert log.recorded == 0
    assert isinstance(rec.span, BatchSpan)


def test_default_buckets_are_sorted_and_nonempty():
    assert list(DEFAULT_BUCKETS) == sorted(DEFAULT_BUCKETS)
    assert DEFAULT_BUCKETS and COUNT_BUCKETS
    with pytest.raises(ValueError):
        Histogram("h", "", (), 8, buckets=())
