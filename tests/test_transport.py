"""Socket broker transport: framing, server/client parity, cross-process
round trips, reconnect, concurrency, and torn-write rejection."""
import multiprocessing as mp
import os
import socket
import struct
import threading
import time
import zlib

import pytest

# The test process has JAX's threads running; os.fork() under threads is
# what the RuntimeWarning warns about, so every subprocess here uses the
# spawn context and this marker turns any regression into a failure.
pytestmark = pytest.mark.filterwarnings(
    "error:os.fork\\(\\) was called:RuntimeWarning")

from repro.core import (Broker, Context, InMemoryPartitionLog, OffsetRange,
                        PartitionLog, StreamingContext)
from repro.data.transport import (MAGIC, BrokerServer, FrameError,
                                  RemoteBroker, TransportError, parse_address,
                                  recv_frame, send_frame, serve_broker)


# -- framing -----------------------------------------------------------------

def _pair():
    a, b = socket.socketpair()
    a.settimeout(5)
    b.settimeout(5)
    return a, b


def test_frame_roundtrip():
    a, b = _pair()
    for payload in (b"", b"x", os.urandom(70_000)):
        send_frame(a, payload)
        assert recv_frame(b) == payload
    a.close()
    assert recv_frame(b) is None          # clean EOF at a frame boundary
    b.close()


def test_torn_frame_rejected():
    a, b = _pair()
    header = struct.pack(">2sII", MAGIC, 100, zlib.crc32(b"irrelevant"))
    a.sendall(header + b"only-16-bytes!!!")    # promises 100, delivers 16
    a.close()
    with pytest.raises(FrameError, match="torn frame"):
        recv_frame(b)
    b.close()


def test_bad_magic_rejected():
    a, b = _pair()
    a.sendall(struct.pack(">2sII", b"ZZ", 4, 0) + b"data")
    with pytest.raises(FrameError, match="bad magic"):
        recv_frame(b)
    a.close(); b.close()


def test_checksum_mismatch_rejected():
    a, b = _pair()
    payload = b"detector-frame-bytes"
    header = struct.pack(">2sII", MAGIC, len(payload),
                         zlib.crc32(payload) ^ 0xDEAD)
    a.sendall(header + payload)
    with pytest.raises(FrameError, match="checksum"):
        recv_frame(b)
    a.close(); b.close()


def test_oversized_length_rejected_before_alloc():
    a, b = _pair()
    a.sendall(struct.pack(">2sII", MAGIC, 1 << 31, 0))
    with pytest.raises(FrameError, match="exceeds"):
        recv_frame(b)
    a.close(); b.close()


def test_parse_address():
    assert parse_address("10.0.0.7:9092") == ("10.0.0.7", 9092)
    assert parse_address(":9092") == ("127.0.0.1", 9092)
    assert parse_address("/tmp/broker.sock") == "/tmp/broker.sock"


# -- PartitionLog protocol extraction ---------------------------------------

class ListBackedLog:
    """Minimal alternate PartitionLog: proves Broker only needs the protocol."""

    def __init__(self):
        self.rows = []
        self._lock = threading.Lock()

    def append(self, key, value, timestamp):
        with self._lock:
            from repro.core.broker import Record
            self.rows.append(Record(key, value, len(self.rows), timestamp))
            return len(self.rows) - 1

    def read(self, start, until):
        with self._lock:
            return self.rows[start:min(until, len(self.rows))]

    def end_offset(self):
        with self._lock:
            return len(self.rows)


def test_partition_log_protocol():
    assert isinstance(InMemoryPartitionLog(), PartitionLog)
    assert isinstance(ListBackedLog(), PartitionLog)


def test_broker_over_custom_log_factory():
    b = Broker(log_factory=ListBackedLog)
    b.create_topic("t", 2)
    for i in range(6):
        b.produce("t", i, partition=i % 2)
    assert [r.value for r in b.read(OffsetRange("t", 0, 0, 9))] == [0, 2, 4]
    assert b.end_offsets("t") == [3, 3]


def test_broker_commit_monotonic_and_lag():
    b = Broker()
    b.create_topic("t", 2)
    for i in range(10):
        b.produce("t", i, partition=i % 2)
    assert b.lag("t") == 10
    b.commit("t", 0, 4)
    b.commit("t", 0, 2)                   # replay never rewinds progress
    b.commit("t", 1, 5)
    assert b.committed("t") == [4, 5]
    assert b.lag("t") == 1
    with pytest.raises(KeyError):
        b.commit("nope", 0, 1)


# -- server/client parity ----------------------------------------------------

@pytest.fixture
def served(tmp_path):
    broker = Broker()
    server = serve_broker(broker, str(tmp_path / "broker.sock"))
    client = RemoteBroker(server.address, max_retries=2, retry_delay=0.01)
    yield broker, server, client
    client.close()
    server.stop()


def test_remote_matches_local(served):
    broker, server, client = served
    assert client.ping()
    client.create_topic("t", 3)
    offs = [client.produce("t", {"i": i}, key=f"k{i}".encode(),
                           partition=i % 3) for i in range(9)]
    assert offs == [0, 0, 0, 1, 1, 1, 2, 2, 2]
    assert client.topics() == broker.topics() == ["t"]
    assert client.num_partitions("t") == 3
    assert client.end_offsets("t") == broker.end_offsets("t") == [3, 3, 3]
    assert client.end_offset("t", 1) == 3
    recs = client.read(OffsetRange("t", 1, 0, 10))
    assert [r.value for r in recs] == [{"i": 1}, {"i": 4}, {"i": 7}]
    assert [r.offset for r in recs] == [0, 1, 2]
    client.commit("t", 0, 3)
    assert broker.committed("t") == [3, 0, 0]
    assert client.lag("t") == broker.lag("t") == 6


def test_remote_raises_broker_errors(served):
    _, _, client = served
    with pytest.raises(KeyError):
        client.end_offsets("missing-topic")
    client.create_topic("t")
    with pytest.raises(ValueError):
        client.create_topic("t")
    assert client.ping()                  # connection survives error frames


def test_remote_numpy_payloads(served):
    np = pytest.importorskip("numpy")
    _, _, client = served
    client.create_topic("frames")
    frame = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    client.produce("frames", (7, frame), key=b"frame-7")
    (rec,) = client.read(OffsetRange("frames", 0, 0, 1))
    idx, got = rec.value
    assert idx == 7 and got.dtype == np.float32
    np.testing.assert_array_equal(got, frame)


# -- cross-process round trip ------------------------------------------------

def _producer_main(address, n):
    from repro.data.transport import RemoteBroker
    client = RemoteBroker(address)
    for i in range(n):
        client.produce("xp", i, key=f"p{i}".encode(), partition=i % 2)
    client.close()


def test_append_read_across_processes(tmp_path):
    broker = Broker()
    broker.create_topic("xp", 2)
    server = serve_broker(broker, ("127.0.0.1", 0))
    try:
        proc = mp.get_context("spawn").Process(
            target=_producer_main, args=(server.address, 40))
        proc.start()
        proc.join(timeout=30)
        assert proc.exitcode == 0
        assert sum(broker.end_offsets("xp")) == 40
        evens = [r.value for r in broker.read(OffsetRange("xp", 0, 0, 99))]
        assert evens == list(range(0, 40, 2))   # per-partition total order
    finally:
        server.stop()


def test_streaming_consumer_over_remote_broker(tmp_path):
    """The consumer side of the split: StreamingContext driven entirely
    through RemoteBroker, commits landing on the served broker."""
    broker = Broker()
    server = serve_broker(broker, str(tmp_path / "b.sock"))
    client = RemoteBroker(server.address)
    try:
        client.create_topic("t", 2)
        for i in range(12):
            client.produce("t", i, partition=i % 2)
        sc = StreamingContext(Context(), client, max_records_per_partition=4)
        sc.subscribe(["t"])
        seen = []
        sc.foreach_batch(lambda rdd, info: seen.extend(rdd.collect()))
        sc.run_batches(3)
        assert sorted(seen) == list(range(12))
        assert broker.committed("t") == [6, 6]   # pushed over the wire
        assert client.lag("t") == 0
    finally:
        client.close()
        server.stop()


# -- reconnect ---------------------------------------------------------------

def test_client_reconnects_after_server_restart():
    broker = Broker()
    broker.create_topic("t")
    server = serve_broker(broker, ("127.0.0.1", 0))
    client = RemoteBroker(server.address, max_retries=6, retry_delay=0.05)
    assert client.produce("t", "before") == 0
    host, port = server.address
    server.stop()
    server2 = BrokerServer(broker, (host, port)).start()
    try:
        assert client.produce("t", "after") == 1      # transparent reconnect
        assert client.reconnects >= 1
        assert [r.value for r in broker.read(OffsetRange("t", 0, 0, 2))] == \
            ["before", "after"]
    finally:
        client.close()
        server2.stop()


def test_retries_are_bounded():
    client = RemoteBroker(("127.0.0.1", 1), connect_timeout=0.2,
                          max_retries=1, retry_delay=0.01)
    with pytest.raises(TransportError, match="unreachable after 2 attempts"):
        client.ping()


# -- concurrency -------------------------------------------------------------

def test_concurrent_producers_one_topic(tmp_path):
    broker = Broker()
    broker.create_topic("t", 1)
    server = serve_broker(broker, str(tmp_path / "b.sock"))
    n_producers, per_producer = 4, 50
    errors = []

    def producer(pid):
        try:
            client = RemoteBroker(server.address)
            for i in range(per_producer):
                client.produce("t", (pid, i), key=f"{pid}-{i}".encode())
            client.close()
        except Exception as e:            # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=producer, args=(p,))
               for p in range(n_producers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    server.stop()
    assert not errors
    recs = broker.read(OffsetRange("t", 0, 0, 10 ** 6))
    assert len(recs) == n_producers * per_producer
    assert [r.offset for r in recs] == list(range(len(recs)))  # dense log
    for p in range(n_producers):          # each producer's order preserved
        assert [v for pid, v in (r.value for r in recs) if pid == p] == \
            list(range(per_producer))


# -- torn writes against a live server --------------------------------------

def test_server_rejects_garbage_and_survives(served):
    _, server, client = served
    client.create_topic("t")
    client.produce("t", 1)
    # a rogue/corrupt peer: valid header promising more bytes than sent
    rogue = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    rogue.connect(server.address)
    rogue.sendall(struct.pack(">2sII", MAGIC, 500, 0) + b"short")
    rogue.close()
    # and one speaking a different protocol entirely
    rogue2 = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    rogue2.connect(server.address)
    rogue2.sendall(b"GET / HTTP/1.1\r\n\r\n")
    rogue2.close()
    deadline = time.monotonic() + 5
    while server.frames_rejected < 2 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert server.frames_rejected == 2
    assert client.produce("t", 2) == 1    # healthy clients unaffected
    assert client.end_offsets("t") == [2]


# -- hardening from review ---------------------------------------------------

def test_wire_unpickler_refuses_dangerous_globals(served):
    """A well-formed frame whose pickle smuggles a callable must be refused
    before instantiation — the server answers with an error, runs nothing."""
    import pickle

    from repro.data.transport import KIND_PICKLE, decode_message

    evil = KIND_PICKLE + pickle.dumps((os.system, ("echo pwned",)))
    with pytest.raises(FrameError, match="refusing to unpickle"):
        decode_message(evil)

    _, server, client = served
    rogue = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    rogue.settimeout(5)
    rogue.connect(server.address)
    send_frame(rogue, evil)
    resp = recv_frame(rogue)
    rogue.close()
    status, exc_name, message = decode_message(resp)
    assert status == "err" and "refusing to unpickle" in message
    assert client.ping()                  # server healthy, nothing executed


def test_oversized_request_fails_fast_no_retries(served, monkeypatch):
    import repro.data.transport as tr
    _, _, client = served
    client.create_topic("t")
    monkeypatch.setattr(tr, "MAX_FRAME_BYTES", 1024)
    with pytest.raises(FrameError, match="exceeds"):
        client.produce("t", b"x" * 4096)
    assert client.reconnects == 0         # rejected before any send/retry
    monkeypatch.undo()
    assert client.produce("t", b"small") == 0


def test_commit_rejects_bad_partition_and_offset(served):
    broker, _, client = served
    client.create_topic("t", 2)
    client.produce("t", 1, partition=0)
    for bad in [("t", -1, 1), ("t", 2, 1), ("t", 0, -1), ("t", 0, 5)]:
        with pytest.raises(ValueError):
            client.commit(*bad)
    assert broker.committed("t") == [0, 0]   # nothing poisoned
    client.commit("t", 0, 1)
    assert broker.committed("t") == [1, 0]


# -- batched produce over the wire -------------------------------------------

def test_ingest_batches_produce_over_remote(tmp_path):
    """IngestRunner's flush buffer amortizes the socket: ~1 produce_many per
    (partition, flush) instead of one round trip per record, with nothing
    lost and per-partition order intact."""
    from repro.data import IngestConfig, IngestRunner, SyntheticRateSource

    broker = Broker()
    server = serve_broker(broker, str(tmp_path / "b.sock"))
    client = RemoteBroker(server.address)
    try:
        runner = IngestRunner(client)
        m = runner.add(SyntheticRateSource(rate=1e9, total=500),
                       IngestConfig(topic="t", partitions=2, poll_batch=100,
                                    flush_records=100, max_pending=1 << 30))
        runner.run_inline(timeout=60)
        assert runner.done
        assert m.produced == 500
        assert sum(broker.end_offsets("t")) == 500
        # 5 polls x 100 records -> 5 flushes x 2 partition groups
        assert m.produce_calls <= 10
        for p in range(2):                 # round-robin kept per-part order
            vals = [r.value for r in broker.read(OffsetRange("t", p, 0, 999))]
            assert vals == list(range(p, 500, 2))
    finally:
        client.close()
        server.stop()


def test_ingest_flush_deadline_and_done():
    """A partially-filled buffer flushes when the oldest record ages past
    flush_interval, and done stays False until the buffer drains."""
    from repro.data import IngestConfig, IngestRunner

    class Trickle:
        def __init__(self):
            self.sent = False
            self.exhausted = False

        def poll(self, max_records):
            if not self.sent:
                self.sent = True
                return [(b"k", "only-record")]
            return []

    broker = Broker()
    runner = IngestRunner(broker)
    source = Trickle()
    m = runner.add(source, IngestConfig(topic="t", flush_records=1000,
                                        flush_interval=0.05))
    runner.pump()
    assert broker.end_offsets("t") == [0]  # buffered, not yet produced
    assert m.produced == 0 and not runner.done
    time.sleep(0.06)
    runner.pump()                          # deadline flush
    assert broker.end_offsets("t") == [1]
    assert m.produced == 1
    source.exhausted = True
    assert runner.done


# -- shared-memory 'S' frames end to end -------------------------------------

def _shm_leftovers() -> list[str]:
    """Segments created by this process's servers still visible in /dev/shm
    (the pool names embed the creator pid, so other processes never alias)."""
    prefix = f"reproshm_{os.getpid()}_"
    try:
        return [f for f in os.listdir("/dev/shm") if f.startswith(prefix)]
    except FileNotFoundError:             # pragma: no cover - non-Linux
        return []


def _wait_no_shm_leftovers(timeout: float = 5.0) -> list[str]:
    deadline = time.monotonic() + timeout
    leftovers = _shm_leftovers()
    while leftovers and time.monotonic() < deadline:
        time.sleep(0.02)
        leftovers = _shm_leftovers()
    return leftovers


def test_shm_negotiated_same_host_end_to_end(served):
    """A same-host UDS client negotiates shm in hello; array-bearing
    produces ride 'S' frames (bulk bytes never on the socket), reads are
    exact, and closing the connection strands nothing in /dev/shm."""
    np = pytest.importorskip("numpy")
    broker, server, client = served
    client.create_topic("frames")
    arrs = [np.arange(i, i + 64 * 64, dtype=np.float32).reshape(64, 64)
            for i in range(6)]
    for i, a in enumerate(arrs):
        client.produce("frames", (i, a), key=f"f{i}".encode())
    assert client.shm_frames_sent == 6
    assert server.shm_frames == 6
    assert server.stats()["shm_segments"] >= 1
    recs = client.read(OffsetRange("frames", 0, 0, 10))
    for i, rec in enumerate(recs):
        idx, got = rec.value
        assert idx == i
        np.testing.assert_array_equal(got, arrs[i])
    client.close()
    assert _wait_no_shm_leftovers() == []


def test_shm_kill_switch_fallback_parity(served, monkeypatch):
    """USE_SHM_FRAMES=False (and shm=False per client) falls back to plain
    'A' frames with identical results — the kill switch is pure mechanism."""
    import numpy as np

    import repro.data.transport as tr

    broker, server, _ = served
    arr = np.arange(128 * 128, dtype=np.float32).reshape(128, 128)

    monkeypatch.setattr(tr, "USE_SHM_FRAMES", False)
    off = RemoteBroker(server.address)
    off.create_topic("t")
    off.produce("t", (0, arr))
    assert off.shm_frames_sent == 0 and server.shm_frames == 0
    off.close()

    monkeypatch.undo()
    optout = RemoteBroker(server.address, shm=False)   # per-client opt-out
    optout.produce("t", (1, arr))
    assert optout.shm_frames_sent == 0 and server.shm_frames == 0
    optout.close()

    on = RemoteBroker(server.address)
    on.produce("t", (2, arr))
    assert on.shm_frames_sent == 1 and server.shm_frames == 1
    recs = on.read(OffsetRange("t", 0, 0, 10))
    on.close()
    assert [r.value[0] for r in recs] == [0, 1, 2]
    for _, got in (r.value for r in recs):             # all three paths equal
        np.testing.assert_array_equal(got, arr)
    assert _wait_no_shm_leftovers() == []


def test_attach_segment_never_touches_resource_tracker(monkeypatch):
    """Attaching a server-owned segment must neither register nor
    unregister it with this process's resource_tracker: a producer spawned
    via ``multiprocessing`` *shares* the server's tracker, so either call
    unbalances the server's own create/unlink pair and the shared tracker
    dies with a KeyError traceback when the server unlinks (regression:
    ``examples/remote_ingest.py`` printed exactly that)."""
    from multiprocessing import resource_tracker, shared_memory

    from repro.data.transport import _attach_untracked, _close_shm

    seg = shared_memory.SharedMemory(
        create=True, size=4096, name=f"reproshm_{os.getpid()}_attachtest")
    calls: list[tuple] = []
    try:
        monkeypatch.setattr(resource_tracker, "register",
                            lambda n, t: calls.append(("register", n, t)))
        monkeypatch.setattr(resource_tracker, "unregister",
                            lambda n, t: calls.append(("unregister", n, t)))
        shm = _attach_untracked(seg.name)
        assert shm.buf is not None and shm.size >= 4096
        _close_shm(shm)
        observed = list(calls)
        # the patched register must be restored, not left swallowing
        assert resource_tracker.register.__name__ == "<lambda>"
    finally:
        monkeypatch.undo()
        seg.close()
        seg.unlink()
    assert observed == []


def test_shm_hello_refuses_foreign_host(served):
    """A hello claiming a different host token is denied shm (descriptors
    would name segments the peer cannot map)."""
    from repro.data.transport import decode_message, send_message

    _, server, _ = served
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.settimeout(5)
    sock.connect(server.address)
    try:
        send_message(sock, ("hello", ({"host": "elsewhere:0000",
                                       "shm": True},), {}))
        status, caps = decode_message(recv_frame(sock))
        assert status == "ok" and caps["shm"] is False
        # and an shm_alloc on the un-negotiated connection declines cleanly
        send_message(sock, ("shm_alloc", (1024,), {}))
        assert decode_message(recv_frame(sock)) == ("ok", None)
    finally:
        sock.close()


_CHAOS_PRODUCER = r"""
import sys
import numpy as np
from repro.data.transport import RemoteBroker

client = RemoteBroker(sys.argv[1])
client.create_topic("chaos")
frame = np.ones((256, 256), dtype=np.float32)
client.produce("chaos", (0, frame))
print("READY", client.shm_frames_sent, flush=True)
while True:
    client.produce("chaos", (0, frame))
"""


def test_sigkill_mid_produce_leaves_no_shm(tmp_path):
    """Chaos pin for the server-owned-segments design: SIGKILL a producer
    mid-stream — the server unlinks every segment the connection leased
    (nothing stranded in /dev/shm) and the dead producer's resource_tracker
    has nothing to complain about (attached segments were unregistered)."""
    import signal
    import subprocess
    import sys

    broker = Broker()
    server = serve_broker(broker, str(tmp_path / "chaos.sock"))
    try:
        proc = subprocess.Popen(
            [sys.executable, "-c", _CHAOS_PRODUCER, server.address],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        try:
            line = proc.stdout.readline()
            assert line.split() == ["READY", "1"], \
                f"producer never negotiated shm: {line!r}"
            assert _shm_leftovers()        # segments live while it streams
            os.kill(proc.pid, signal.SIGKILL)
            proc.wait(timeout=10)
            # EOF arrives only once the producer's resource_tracker (the
            # last writer on the inherited pipe) has exited too — so this
            # read observes any leak warning it would ever print
            stderr = proc.stderr.read()
        finally:
            proc.stdout.close()
            proc.stderr.close()
        assert "resource_tracker" not in stderr, stderr
        assert "leaked" not in stderr, stderr
        assert _wait_no_shm_leftovers() == []
        assert server.stats()["shm_segments"] == 0
        assert broker.end_offsets("chaos")[0] >= 1   # it did stream for real
    finally:
        server.stop()


def test_ingest_add_tolerates_create_race():
    """Two producers' check-then-create on one topic must not kill the
    loser (the topic appearing between topics() and create_topic)."""
    from repro.data import IngestConfig, IngestRunner, SyntheticRateSource

    class RacyBroker(Broker):
        def topics(self):
            return []                     # always claims the topic is absent

    broker = RacyBroker()
    runner = IngestRunner(broker)
    runner.add(SyntheticRateSource(rate=1e9, total=1), IngestConfig(topic="t"))
    runner.add(SyntheticRateSource(rate=1e9, total=1), IngestConfig(topic="t"))
    assert Broker.topics(broker) == ["t"]


# -- op allow-list parity ----------------------------------------------------

def test_op_allowlist_parity():
    """Runtime complement to the static `transport-op-parity` rule: the
    _OPS allow-list, the server dispatch, and RemoteBroker's public
    surface must describe the same protocol — checked against the live
    objects, so ops built or decorated dynamically still count."""
    import inspect

    from repro.data import transport as t

    # ops the transport itself answers without touching the broker
    server_local = {"ping", "stats", "hello", "shm_alloc"}
    # connection internals issued by _connect/_send_shm, not a public method
    connection_internal = {"hello", "shm_alloc"}

    # every broker-bound op in _OPS is a real callable on Broker — the
    # server's getattr(self.broker, op) can never fall over
    for op in sorted(t._OPS - server_local):
        assert callable(getattr(t.Broker, op, None)), (
            f"allow-listed op {op!r} is not a Broker method")

    # drive every public RemoteBroker method against a recording stub and
    # diff the ops it issues against the allow-list
    rb = t.RemoteBroker.__new__(t.RemoteBroker)
    issued: set[str] = set()
    rb._request = lambda op, *a, **k: issued.add(op)

    dummy = {"rng": t.OffsetRange("t", 0, 0, 0), "pairs": [],
             "topics": ["t"], "cursors": {}, "hwms": {}}

    def arg_for(param):
        if param.name in dummy:
            return dummy[param.name]
        if param.annotation in (int, "int"):
            return 0
        return "x"

    public = [name for name, fn in vars(t.RemoteBroker).items()
              if inspect.isfunction(fn) and not name.startswith("_")
              and name != "close"]
    for name in public:
        fn = getattr(rb, name)
        sig = inspect.signature(fn)
        args = [arg_for(p) for p in sig.parameters.values()
                if p.default is inspect.Parameter.empty
                and p.kind in (p.POSITIONAL_ONLY,
                               p.POSITIONAL_OR_KEYWORD)]
        fn(*args)

    unlisted = issued - t._OPS
    assert not unlisted, f"RemoteBroker issues ops outside _OPS: {unlisted}"
    uncovered = t._OPS - issued - connection_internal
    assert not uncovered, (
        f"allow-listed ops with no public RemoteBroker issuer: {uncovered}")
