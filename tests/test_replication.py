"""Broker HA: follower replication, promotion, fencing, failover.

The acceptance bar (docs/replication.md): SIGKILL the primary mid-stream
with a live consumer group attached — the follower promotes, producers and
consumers re-point through :class:`FailoverBroker`, the stream resumes, and
the consumed record *set* equals an uncrashed run's (no committed record
lost; duplicates absorbed downstream by idempotent-by-key semantics, a set
here). A fenced old primary is rejected if it comes back.
"""
import os
import subprocess
import sys
import time

import pytest

from repro.core import Broker, Context, OffsetRange, StreamingContext
from repro.core.broker import (COMMIT_TOPIC, BrokerFencedError,
                               NotPrimaryError)
from repro.data.durable_log import DurableLogFactory
from repro.data.replication import FailoverBroker, ReplicaFollower
from repro.data.transport import RemoteBroker, serve_broker


def _durable_primary(tmp_path, name="primary"):
    factory = DurableLogFactory(str(tmp_path / name))
    return Broker(log_factory=factory, commit_topic=COMMIT_TOPIC), factory


# -- replication: frames cross verbatim -------------------------------------

def test_follower_log_is_byte_identical(tmp_path):
    primary, _ = _durable_primary(tmp_path)
    server = serve_broker(primary, str(tmp_path / "p.sock"))
    primary.create_topic("t", 2)
    primary.produce_many("t", [(f"k{i}".encode(), {"i": i})
                               for i in range(40)])
    fol = ReplicaFollower(server.address, str(tmp_path / "replica"))
    try:
        while fol.sync_once():
            pass
        assert fol.broker.end_offsets("t") == primary.end_offsets("t")
        # same records at the same offsets...
        for p in range(2):
            end = primary.end_offset("t", p)
            assert ([r.value for r in
                     fol.broker.read(OffsetRange("t", p, 0, end))]
                    == [r.value for r in
                        primary.read(OffsetRange("t", p, 0, end))])
        # ...and the segment *files* hold the same bytes: the CRC frame is
        # the wire format, shipped verbatim, so the logs are byte-identical
        for p in range(2):
            pdir = tmp_path / "primary" / "t" / f"p{p:04d}"
            fdir = tmp_path / "replica" / "t" / f"p{p:04d}"
            psegs = sorted(f for f in os.listdir(pdir)
                           if f.endswith(".seg"))
            assert psegs == sorted(f for f in os.listdir(fdir)
                                   if f.endswith(".seg"))
            for seg in psegs:
                assert (pdir / seg).read_bytes() == (fdir / seg).read_bytes()
        # the follower reported its high-watermarks back to the primary
        hwms = primary.replica_hwm()
        assert hwms[fol.replica_id]["t"] == primary.end_offsets("t")
    finally:
        fol.stop()
        server.stop()


def test_inmemory_primary_is_replicable(tmp_path):
    """fetch_frames on an in-memory broker frames records on the fly; the
    durable follower still re-verifies CRCs and lands identical records."""
    primary = Broker()
    server = serve_broker(primary, str(tmp_path / "p.sock"))
    primary.create_topic("t", 1)
    primary.produce_many("t", [(None, i) for i in range(10)], partition=0)
    fol = ReplicaFollower(server.address, str(tmp_path / "replica"))
    try:
        while fol.sync_once():
            pass
        got = fol.broker.read(OffsetRange("t", 0, 0, 10))
        assert [r.value for r in got] == list(range(10))
        assert [r.offset for r in got] == list(range(10))
    finally:
        fol.stop()
        server.stop()


def _split(blob: bytes, lengths: list[int]) -> list[bytes]:
    """Cut a fetch_frames/read_frames blob back into individual frames."""
    out, cut = [], 0
    for n in lengths:
        out.append(bytes(blob[cut:cut + n]))
        cut += n
    return out


def test_append_frames_rejects_corruption(tmp_path):
    log_ = DurableLogFactory(str(tmp_path / "wal"))(topic="t", partition=0)
    src = DurableLogFactory(str(tmp_path / "src"))(topic="t", partition=0)
    for i in range(3):
        src.append(b"k", i, 0.0)
    frames = _split(*src.read_frames(0, 3)[:2])
    bad = bytearray(frames[1])
    bad[-1] ^= 0xFF                       # flip one payload byte
    with pytest.raises(ValueError):
        log_.append_frames([frames[0], bytes(bad), frames[2]])
    assert log_.end_offset() == 0          # all-or-nothing: nothing landed
    assert log_.append_frames(frames) == [0, 1, 2]
    assert [r.value for r in log_.read(0, 3)] == [0, 1, 2]


# -- promotion & fencing matrix ----------------------------------------------

def test_replica_rejects_writes_until_promoted():
    replica = Broker(writable=False)
    replica.create_topic("t", 1)           # mirroring topics is allowed
    with pytest.raises(NotPrimaryError):
        replica.produce("t", 1)
    with pytest.raises(NotPrimaryError):
        replica.produce_many("t", [(None, 1)])
    with pytest.raises(NotPrimaryError):
        replica.commit("t", 0, 0)
    with pytest.raises(NotPrimaryError):
        replica.join_group("g", "c1", ["t"])
    assert replica.broker_epoch() == {"epoch": 0, "writable": False}
    assert replica.promote(3) == {"epoch": 3, "promoted": True,
                                  "writable": True}
    assert replica.produce("t", 1) == 0    # writable now
    # idempotent across racing clients at the same (or an older) epoch
    assert replica.promote(3)["promoted"] is False
    with pytest.raises(ValueError):
        Broker(writable=False, epoch=5).promote(5)   # not strictly newer


def test_fencing_rejects_zombie_writes():
    primary = Broker()
    primary.create_topic("t", 1)
    primary.produce("t", 0)
    with pytest.raises(ValueError):
        primary.fence(0)                   # stale fence attempt is rejected
    assert primary.fence(2)["writable"] is False
    for attempt in (lambda: primary.produce("t", 1),
                    lambda: primary.produce_many("t", [(None, 1)]),
                    lambda: primary.commit("t", 0, 1),
                    lambda: primary.join_group("g", "c", ["t"])):
        with pytest.raises(BrokerFencedError):
            attempt()
    assert primary.end_offset("t") == 1    # nothing slipped through
    # a fenced broker can only rejoin by promoting ABOVE the fence epoch
    with pytest.raises(ValueError):
        primary.promote(2)
    assert primary.promote(4)["promoted"] is True
    assert primary.produce("t", 1) == 1


def test_fencing_errors_cross_the_wire_typed(tmp_path):
    replica = Broker(writable=False)
    replica.create_topic("t", 1)
    server = serve_broker(replica, str(tmp_path / "r.sock"))
    client = RemoteBroker(server.address, max_retries=1, retry_delay=0.01)
    try:
        with pytest.raises(NotPrimaryError):
            client.produce("t", 1)
        client.promote(1)
        assert client.produce("t", 1) == 0
        client.fence(9)
        with pytest.raises(BrokerFencedError):
            client.produce("t", 2)
        assert client.broker_epoch()["writable"] is False
    finally:
        client.close()
        server.stop()


# -- group/committed state across restart and failover ------------------------

def test_restart_rebuilds_group_commits_from_commit_topic(tmp_path):
    """Broker restart durability matrix: committed offsets per group and the
    coordinator's generation floor survive (via the durable ``__commits``
    topic); group *membership* does not — members must rejoin, which is what
    keeps zombie members at stale generations fenced after a restart."""
    broker, factory = _durable_primary(tmp_path)
    broker.create_topic("t", 2)
    broker.produce_many("t", [(None, i) for i in range(8)], partition=0)
    broker.produce_many("t", [(None, i) for i in range(4)], partition=1)
    broker.commit("t", 0, 5, group="g1")
    broker.commit("t", 1, 3, group="g1")
    broker.commit("t", 0, 2, group="g2")
    out = broker.join_group("grp", "c1", ["t"])    # bumps grp generation
    gen = out["generation"]

    reborn = Broker(log_factory=DurableLogFactory(str(tmp_path / "primary")),
                    commit_topic=COMMIT_TOPIC)
    factory.restore(reborn)
    assert reborn.restore_commits() > 0
    assert reborn.committed("t", group="g1") == [5, 3]
    assert reborn.committed("t", group="g2") == [2, 0]
    # generation floor survived: the next join lands strictly above it
    assert reborn.join_group("grp", "c2", ["t"])["generation"] > gen
    # membership itself did not survive — c1 is unknown until it rejoins
    assert list(reborn.describe_group("grp")["members"]) == ["c2"]


def test_restore_commits_clamps_to_local_log_end(tmp_path):
    """A replicated commit record can outrun replication of the data it
    points at; the rebuilt offset must clamp to the local log end or every
    reader would wedge waiting for records that do not exist."""
    broker, factory = _durable_primary(tmp_path)
    broker.create_topic("t", 1)
    broker.produce_many("t", [(None, i) for i in range(10)], partition=0)
    broker.commit("t", 0, 10, group="g")

    # follower-side rebuild where only 4 of the 10 records made it
    short = Broker(log_factory=DurableLogFactory(str(tmp_path / "f")),
                   commit_topic=COMMIT_TOPIC)
    short.create_topic("t", 1)
    short.create_topic(COMMIT_TOPIC, 1)
    frames = _split(*broker.fetch_frames("t", 0, 0)[:2])
    short._topic("t")[0].append_frames(frames[:4])
    cframes = _split(*broker.fetch_frames(COMMIT_TOPIC, 0, 0)[:2])
    short._topic(COMMIT_TOPIC)[0].append_frames(cframes)
    short.restore_commits()
    assert short.committed("t", group="g") == [4]


# -- failover: promotion + resend window --------------------------------------

def test_failover_promotes_and_resends_unreplicated_tail(tmp_path):
    primary, _ = _durable_primary(tmp_path)
    pserver = serve_broker(primary, str(tmp_path / "p.sock"))
    primary.create_topic("t", 2)
    fol = ReplicaFollower(pserver.address, str(tmp_path / "replica"),
                          poll_interval=0.005)
    faddr = fol.serve(str(tmp_path / "f.sock"))
    fol.start()
    fb = FailoverBroker([pserver.address, faddr])
    try:
        fb.produce_many("t", [(f"k{i}".encode(), i) for i in range(30)])
        assert fb.flush(timeout=10)        # follower confirmed everything
        assert fb.pending_batches == 0
        assert fol.broker.end_offsets("t") == primary.end_offsets("t")

        # stall the pull loop, then produce a tail the follower never sees
        fol.poll_interval = 60
        time.sleep(0.05)
        fb.produce_many("t", [(b"tail%d" % i, 100 + i) for i in range(10)],
                        partition=0)
        assert fb.pending_batches >= 1     # unconfirmed: still in the window
        pserver.stop()                     # primary dies with the tail

        # next call fails over: follower promoted, tail re-sent, call served
        fb.produce_many("t", [(b"post", 999)], partition=1)
        assert fb.failovers == 1
        assert fb.epoch == 1
        assert fb.active_address == faddr
        assert fol.promoted
        got = {r.value
               for p in range(2)
               for r in fb.read(OffsetRange("t", p, 0,
                                            fb.end_offset("t", p)))}
        assert {100 + i for i in range(10)} <= got   # no committed loss
        assert 999 in got
        # the follower's EPOCH file pins the promotion durably
        deadline = time.monotonic() + 5
        while not os.path.exists(tmp_path / "replica" / "EPOCH"):
            assert time.monotonic() < deadline
            time.sleep(0.01)
        assert (tmp_path / "replica" / "EPOCH").read_text() == "1"
    finally:
        fb.close()
        fol.stop()
        pserver.stop()


def test_no_replica_degrades_to_primary_ack(tmp_path):
    """With no follower attached the resend window collapses: primary ack =
    committed (exactly the pre-HA contract)."""
    primary, _ = _durable_primary(tmp_path)
    server = serve_broker(primary, str(tmp_path / "p.sock"))
    fb = FailoverBroker([server.address])
    try:
        fb.create_topic("t", 1)
        fb.produce_many("t", [(None, i) for i in range(5)], partition=0)
        assert fb.flush(timeout=5)
        assert fb.pending_batches == 0
    finally:
        fb.close()
        server.stop()


def test_streaming_context_rebases_cursor_after_failover():
    """After a failover the new primary's log may be shorter than the
    consumer's cursor (lost unreplicated tail): the context must clamp its
    start offsets or it would skip every record the new primary appends."""
    b = Broker()
    b.failovers = 0                       # quack like a FailoverBroker
    b.create_topic("t", 1)
    for i in range(6):
        b.produce("t", i)
    ctx = Context()
    sc = StreamingContext(ctx, b)
    sc.subscribe(["t"])
    seen = []
    sc.foreach_batch(lambda rdd, info: seen.extend(rdd.collect()))
    sc.run_one_batch()
    assert seen == [0, 1, 2, 3, 4, 5]

    # "failover": replace the log with a shorter replica (4 of 6 records)
    shorter = Broker()
    shorter.create_topic("t", 1)
    for i in range(4):
        shorter.produce("t", i)
    sc.broker = b = shorter
    b.failovers = 1
    assert sc.run_one_batch() is None      # rebase only; nothing new yet
    b.produce("t", 99)                     # lands at offset 4 < old cursor 6
    sc.run_one_batch()
    assert seen[6:] == [99]                # consumed, not silently skipped


# -- chaos acceptance: SIGKILL the primary mid-stream -------------------------

_PRIMARY_PROC = """\
import sys, time
from repro.core.broker import Broker, COMMIT_TOPIC
from repro.data.durable_log import DurableLogFactory
from repro.data.transport import serve_broker
factory = DurableLogFactory(sys.argv[1])
broker = Broker(log_factory=factory, commit_topic=COMMIT_TOPIC)
factory.restore(broker)
broker.restore_commits()
serve_broker(broker, sys.argv[2])
print("ready", flush=True)
while True:
    time.sleep(1)
"""


def _spawn_primary(root, sock):
    if os.path.exists(sock):
        os.unlink(sock)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(os.path.dirname(__file__)), "src"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.Popen([sys.executable, "-c", _PRIMARY_PROC,
                             str(root), sock],
                            stdout=subprocess.PIPE, env=env, text=True)
    assert proc.stdout.readline().strip() == "ready"
    return proc


def test_chaos_sigkill_primary_with_live_consumer_group(tmp_path):
    psock = str(tmp_path / "p.sock")
    proc = _spawn_primary(tmp_path / "primary", psock)
    fol = fb = None
    try:
        fol = ReplicaFollower(psock, str(tmp_path / "replica"),
                              poll_interval=0.005)
        faddr = fol.serve(str(tmp_path / "f.sock"))
        fol.start()
        fb = FailoverBroker([psock, faddr])
        fb.create_topic("t", 2)

        consumed = set()
        sc = StreamingContext(Context(), fb)
        sc.subscribe(["t"])
        sc.join_group("grp", "c1", heartbeat_interval=0.05,
                      session_timeout=2.0)
        sc.foreach_batch(
            lambda rdd, info: consumed.update(v for v in rdd.collect()))

        total, chunk, kill_at = 200, 20, 100
        produced = set()
        for base in range(0, total, chunk):
            vals = list(range(base, base + chunk))
            fb.produce_many("t", [(str(v).encode(), v) for v in vals])
            produced.update(vals)
            if base + chunk == kill_at:
                proc.kill()                # SIGKILL mid-stream
                proc.wait()
            sc.run_one_batch()

        assert fb.failovers >= 1           # the stream rode through a death
        assert fb.active_address == faddr
        fb.flush(timeout=10)
        deadline = time.monotonic() + 20
        while consumed != produced and time.monotonic() < deadline:
            if sc.run_one_batch() is None:
                time.sleep(0.01)
        # the consumed SET equals the uncrashed run's: every committed
        # record arrived; duplicates (resent window) collapsed in the set
        assert consumed == produced

        # the old primary returns from the dead on the same address: it must
        # be fenced, not allowed to accept writes at its stale epoch
        proc = _spawn_primary(tmp_path / "primary", psock)
        assert fb.fence_stale() == [psock]
        zombie = RemoteBroker(psock, max_retries=1, retry_delay=0.01)
        try:
            with pytest.raises(BrokerFencedError):
                zombie.produce("t", -1, partition=0)
        finally:
            zombie.close()
    finally:
        if fb is not None:
            fb.close()
        if fol is not None:
            fol.stop()
        if proc.poll() is None:
            proc.kill()
            proc.wait()
