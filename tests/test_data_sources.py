"""Data sources + ingest runtime: replay determinism, seek/resume via
StreamProgress, and backpressure policies under a fast SyntheticRateSource."""
import json
import os

import numpy as np
import pytest

from repro.core import Broker, Context, StreamingContext
from repro.data import (DetectorSource, FileReplaySource, IngestConfig,
                        IngestRunner, ProjectionSource, SyntheticRateSource,
                        TopicSource, ingest_all, save_npz_capture)


# -- replay determinism ------------------------------------------------------

def test_npz_replay_is_deterministic(tmp_path):
    path = str(tmp_path / "capture.npz")
    frames = [(f"frame-{i}", np.full((4, 4), i, np.float32)) for i in range(9)]
    save_npz_capture(path, frames)
    a = FileReplaySource(path)
    b = FileReplaySource(path)
    ra, rb = a.poll(100), b.poll(100)
    assert [k for k, _ in ra] == [k for k, _ in rb]
    assert len(ra) == 9 and a.exhausted
    for i, (key, val) in enumerate(ra):
        assert key.decode().endswith(f"frame-{i}")
        np.testing.assert_array_equal(val, frames[i][1])


def test_jsonl_replay_preserves_file_order(tmp_path):
    path = str(tmp_path / "events.jsonl")
    events = [{"i": i, "v": i * i} for i in range(7)]
    with open(path, "w") as f:
        for e in events:
            f.write(json.dumps(e) + "\n")
    src = FileReplaySource(path)
    assert [v for _, v in src.poll(100)] == events


def test_seek_replays_same_records(tmp_path):
    path = str(tmp_path / "c.npz")
    save_npz_capture(path, [(f"x{i}", np.arange(i + 1)) for i in range(6)])
    src = FileReplaySource(path)
    first = src.poll(4)
    src.seek(0)
    again = src.poll(4)
    assert [k for k, _ in first] == [k for k, _ in again]
    src.seek(5)
    assert src.position == 5 and len(src.poll(10)) == 1
    with pytest.raises(ValueError):
        src.seek(99)


def test_detector_and_projection_sources_match_apps():
    from repro.apps.ptycho.sim import simulate
    problem = simulate(64, 16, 12)
    det = DetectorSource(problem, max_frames=10)
    recs = det.poll(100)
    assert [v for _, v in recs] == list(range(10)) and det.exhausted

    det2 = DetectorSource(problem, max_frames=3, emit_frames=True)
    (_, (idx, frame)), = det2.poll(1)
    assert idx == 0
    np.testing.assert_allclose(frame, np.asarray(problem.magnitudes[0]))

    sino = np.arange(20, dtype=np.float32).reshape(5, 4)
    proj = ProjectionSource(sino)
    vals = [v for _, v in proj.poll(100)]
    assert [i for i, _ in vals] == list(range(5))
    np.testing.assert_array_equal(vals[3][1], sino[3])


# -- seek/resume after restart via StreamProgress ----------------------------

def test_source_resume_after_restart(tmp_path):
    """Kill the context mid-stream; a new context over the same checkpoint
    resumes without reprocessing or re-producing records."""
    ckpt = str(tmp_path / "progress.json")
    broker = Broker()
    src = SyntheticRateSource(rate=1e9, total=20)
    sc = StreamingContext(Context(), broker, max_records_per_partition=4,
                          checkpoint_path=ckpt)
    sc.subscribe_source(src, topic="t")
    got: list[int] = []
    sc.foreach_batch(lambda rdd, info: got.extend(rdd.collect()))
    sc.run_one_batch()
    sc.run_one_batch()
    assert got == list(range(8))

    # "crash": new context + NEW source instance over the same broker/ckpt;
    # subscribe_source seeks the source past what the broker already holds.
    src2 = SyntheticRateSource(rate=1e9, total=20)
    sc2 = StreamingContext(Context(), broker, max_records_per_partition=4,
                          checkpoint_path=ckpt)
    sc2.subscribe_source(src2, topic="t")
    got2: list[int] = []
    sc2.foreach_batch(lambda rdd, info: got2.extend(rdd.collect()))
    while not (sc2.sources_exhausted and sc2.lag("t") == 0):
        sc2.run_one_batch()
    assert got2 == list(range(8, 20))
    # nothing was double-produced into the log
    assert sum(broker.end_offsets("t")) == 20


def test_topic_source_seek_is_total_position():
    """seek(n) repositions by total records emitted, distributed over
    partitions in drain order — the contract subscribe_source relies on
    when resuming a chained stage."""
    broker = Broker()
    broker.create_topic("src", 2)
    for i in range(10):
        broker.produce("src", i, partition=i % 2)   # p0: evens, p1: odds
    ts = TopicSource(broker, "src", stop_at_end=True)
    first = [v for _, v in ts.poll(100)]
    assert ts.position == 10
    ts.seek(7)                          # p0 fully drained (5) + 2 of p1
    rest = [v for _, v in ts.poll(100)]
    assert first[7:] == rest == [5, 7, 9]


def test_subscribe_source_fills_all_partitions_per_batch():
    """max_records_per_partition is a per-partition cap: a 2-partition
    source topic gets 2x records pumped per micro-batch, matching what the
    consumer can drain."""
    broker = Broker()
    sc = StreamingContext(Context(), broker, max_records_per_partition=8)
    sc.subscribe_source(SyntheticRateSource(rate=1e9, total=32),
                        topic="t", partitions=2)
    info = sc.run_one_batch()
    assert info.num_records == 16       # 8 per partition, both filled


def test_topic_source_chains_stages():
    """Stage 1 topic re-ingested as stage 2's source (multi-stage pipeline)."""
    broker = Broker()
    broker.create_topic("stage1", 2)
    for i in range(10):
        broker.produce("stage1", i, partition=i % 2)
    src = TopicSource(broker, "stage1", stop_at_end=True)
    sc = StreamingContext(Context(), broker)
    sc.subscribe_source(src, topic="stage2")
    got: list[int] = []
    sc.foreach_batch(lambda rdd, info: got.extend(rdd.collect()))
    while not (sc.sources_exhausted and sc.lag("stage2") == 0):
        if sc.run_one_batch() is None:
            break
    assert sorted(got) == list(range(10))
    assert src.exhausted


# -- backpressure ------------------------------------------------------------

def _drain(sc, runner, topic, max_iters=10000):
    i = 0
    while (not runner.done or sc.lag(topic) > 0) and i < max_iters:
        sc.run_one_batch()
        i += 1
    assert i < max_iters, "pipeline never drained"


def test_backpressure_block_policy_bounds_lag():
    broker = Broker()
    sc = StreamingContext(Context(), broker, max_records_per_partition=8)
    runner = IngestRunner(broker, consumer=sc)
    fast = SyntheticRateSource(rate=1e9, total=300)
    cfg = IngestConfig(topic="t", policy="block", max_pending=16,
                       poll_batch=64)
    m = runner.add(fast, cfg)
    sc.subscribe(["t"])
    sc.foreach_batch(lambda rdd, info: rdd.count())
    seen_lags = []
    while not runner.done or sc.lag("t") > 0:
        runner.pump()                       # inline: deterministic interleave
        seen_lags.append(sc.lag("t"))
        sc.run_one_batch()
    assert m.produced == 300 and m.dropped == 0
    assert max(seen_lags) <= cfg.max_pending       # block never overshoots
    assert m.max_observed_lag <= cfg.max_pending


def test_backpressure_drop_policy_sheds_load():
    broker = Broker()
    sc = StreamingContext(Context(), broker, max_records_per_partition=4)
    runner = IngestRunner(broker, consumer=sc)
    fast = SyntheticRateSource(rate=1e9, total=400)
    cfg = IngestConfig(topic="t", policy="drop", max_pending=8, poll_batch=32)
    m = runner.add(fast, cfg)
    sc.subscribe(["t"])
    sc.foreach_batch(lambda rdd, info: rdd.count())
    # producer runs much faster than the consumer: pump many rounds per batch
    while not runner.done or sc.lag("t") > 0:
        for _ in range(4):
            runner.pump()
        sc.run_one_batch()
    assert m.dropped > 0                           # load was shed...
    assert m.produced + m.dropped == 400           # ...and accounted for
    assert m.max_observed_lag <= cfg.max_pending + cfg.poll_batch


def test_backpressure_sample_policy_thins_stream():
    broker = Broker()
    sc = StreamingContext(Context(), broker, max_records_per_partition=4)
    runner = IngestRunner(broker, consumer=sc)
    fast = SyntheticRateSource(rate=1e9, total=400)
    cfg = IngestConfig(topic="t", policy="sample", max_pending=8,
                       poll_batch=32, sample_stride=4)
    m = runner.add(fast, cfg)
    sc.subscribe(["t"])
    got: list[int] = []
    sc.foreach_batch(lambda rdd, info: got.extend(rdd.collect()))
    while not runner.done or sc.lag("t") > 0:
        for _ in range(4):
            runner.pump()
        sc.run_one_batch()
    assert m.sampled_out > 0
    assert m.produced + m.sampled_out == 400
    assert sorted(got) == got                      # thinned but still ordered


def test_ingest_runner_thread_and_rate_limit():
    broker = Broker()
    sc = StreamingContext(Context(), broker, max_records_per_partition=50)
    runner = IngestRunner(broker, consumer=sc)
    src = SyntheticRateSource(rate=1e9, total=120)
    m = runner.add(src, IngestConfig(topic="t", rate_limit=4000.0,
                                     poll_batch=16))
    sc.subscribe(["t"])
    got: list[int] = []
    sc.foreach_batch(lambda rdd, info: got.extend(rdd.collect()))
    runner.start()
    assert runner.join(timeout=30)
    runner.stop()
    while sc.lag("t") > 0:
        sc.run_one_batch()
    assert got == list(range(120)) and m.produced == 120
    # rate-limited: 120 records at 4k rec/s need >= ~25 ms
    assert m.throughput <= 4000.0 * 1.5 + 1e-9


def test_ingest_all_convenience():
    broker = Broker()
    a = SyntheticRateSource(rate=1e9, total=5)
    b = SyntheticRateSource(rate=1e9, total=7, value_fn=lambda i: -i)
    ms = ingest_all(broker, [(a, IngestConfig(topic="ta")),
                             (b, IngestConfig(topic="tb"))])
    assert [m.produced for m in ms] == [5, 7]
    assert sum(broker.end_offsets("ta")) == 5
    assert sum(broker.end_offsets("tb")) == 7


def test_run_inline_zero_timeout_gives_up_immediately():
    """timeout=0: one pump pass, then give up. A slow source must not turn
    run_inline into an infinite loop — the deadline is ``is not None``
    tested, so 0 is a real (already expired) deadline, not "no deadline"."""
    import time

    broker = Broker()
    runner = IngestRunner(broker)
    # first record due in ~10^6 seconds: every pump moves nothing
    runner.add(SyntheticRateSource(rate=1e-6, total=3),
               IngestConfig(topic="t"))
    t0 = time.perf_counter()
    runner.run_inline(timeout=0)
    assert time.perf_counter() - t0 < 1.0
    assert sum(broker.end_offsets("t")) == 0
