"""Model substrate: all 10 archs — loss, shapes, serve-path consistency,
family-specific oracles (rwkv chunked vs recurrent, rglru scan vs step)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_config
from repro.models import rwkv6
from repro.models.registry import get_model


def make_batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["tokens"] = jax.random.randint(
            key, (B, S - cfg.num_image_tokens), 0, cfg.vocab_size)
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", all_archs())
def test_arch_smoke_loss_and_specs(arch):
    """Reduced config: one train-loss eval, finite, spec tree matches."""
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    specs = model.param_specs(cfg)
    assert jax.tree_util.tree_structure(params) == \
        jax.tree_util.tree_structure(
            specs, is_leaf=lambda x: isinstance(x, tuple))
    loss, metrics = jax.jit(
        lambda p, b: model.loss_and_metrics(p, b, cfg))(
        params, make_batch(cfg, key))
    assert np.isfinite(float(loss))
    assert float(loss) < 3.0 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", all_archs())
def test_prefill_then_decode_matches_full_forward(arch):
    """Greedy continuation via (prefill + decode_step) must equal the
    argmax of teacher-forced full forwards — the serve-path invariant."""
    cfg = get_config(arch, reduced=True)
    if cfg.attention_impl == "blocked":
        cfg = cfg.replace(attention_impl="naive")
    if cfg.num_experts:
        # capacity-limited MoE routing is sequence-dependent (dropping a
        # token depends on its neighbours), so the serve invariant only
        # holds drop-free — same setup as the a2a dispatch test.
        cfg = cfg.replace(capacity_factor=4.0)
    model = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key, cfg)
    B, S, G = 2, 12, 4
    batch = make_batch(cfg, key, B=B, S=S)

    # serve path
    logits, cache = model.prefill(params, batch, cfg, max_len=S + G)
    serve_tokens = [jnp.argmax(logits[:, -1], -1)]
    for _ in range(G - 1):
        logits, cache = model.decode_step(
            params, serve_tokens[-1][:, None].astype(jnp.int32), cache, cfg)
        serve_tokens.append(jnp.argmax(logits[:, -1], -1))
    serve_tokens = jnp.stack(serve_tokens, axis=1)

    # teacher-forced path: full forward over prompt+generated each step
    full_tokens = batch["tokens"]
    for g in range(G):
        b2 = dict(batch)
        b2["tokens"] = full_tokens
        logits2, _ = model.prefill(params, b2, cfg,
                                   max_len=full_tokens.shape[1] + 1)
        nxt = jnp.argmax(logits2[:, -1], -1)
        np.testing.assert_array_equal(np.asarray(nxt),
                                      np.asarray(serve_tokens[:, g]),
                                      err_msg=f"{arch} step {g}")
        full_tokens = jnp.concatenate(
            [full_tokens, nxt[:, None].astype(jnp.int32)], axis=1)


def test_rwkv_chunked_equals_recurrent():
    """The chunked parallel wkv (training path) must equal the sequential
    recurrence (decode path) — same math, two schedules."""
    key = jax.random.PRNGKey(2)
    B, T, H, K = 2, 21, 3, 8
    r, k, v = (jax.random.normal(kk, (B, T, H, K))
               for kk in jax.random.split(key, 3))
    logw = -jnp.exp(jax.random.normal(jax.random.PRNGKey(3),
                                      (B, T, H, K)) * 2 - 1.0)
    u = jax.random.normal(jax.random.PRNGKey(4), (H, K))
    s0 = jnp.zeros((B, H, K, K))
    y1, st1 = rwkv6._wkv_chunked(r, k, v, logw, u, s0, chunk=5)
    y2, st2 = rwkv6._wkv_recurrent(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               rtol=2e-4, atol=2e-4)


def test_rwkv_chunked_extreme_decay_is_stable():
    """Near-zero decay (w -> 0, the overflow trap for naive chunking) must
    not produce NaN/Inf — the log-space-difference guarantee."""
    key = jax.random.PRNGKey(5)
    B, T, H, K = 1, 16, 2, 4
    r, k, v = (jax.random.normal(kk, (B, T, H, K))
               for kk in jax.random.split(key, 3))
    logw = jnp.full((B, T, H, K), -150.0)     # w = e^-150 ~ 0
    u = jnp.ones((H, K))
    y, st = rwkv6._wkv_chunked(r, k, v, logw, u,
                               jnp.zeros((B, H, K, K)), chunk=8)
    assert np.all(np.isfinite(np.asarray(y)))
    y2, _ = rwkv6._wkv_recurrent(r, k, v, logw, u, jnp.zeros((B, H, K, K)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_rglru_scan_equals_stepwise():
    from repro.models import rglru
    cfg = get_config("recurrentgemma-2b", reduced=True)
    key = jax.random.PRNGKey(6)
    p = rglru._init_rec_block(key, cfg, jnp.float32)
    B, T, W = 2, 9, cfg.lru_width
    x = jax.random.normal(jax.random.PRNGKey(7), (B, T, W))
    h0 = jnp.zeros((B, W))
    y_par, h_par = rglru._rg_lru(x, p, h0)
    h = h0
    ys = []
    for t in range(T):
        y_t, h = rglru._rg_lru_step(x[:, t], p, h)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h),
                               rtol=2e-5, atol=2e-5)


def test_moe_router_capacity_and_gates():
    from repro.models.moe import _positions_in_expert, moe_layer
    # positions-in-expert: stable ranks
    e = jnp.asarray([2, 0, 2, 1, 2, 0], jnp.int32)
    pos = _positions_in_expert(e, 3)
    np.testing.assert_array_equal(np.asarray(pos), [0, 0, 1, 0, 2, 1])

    cfg = get_config("granite-moe-3b-a800m", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(8), cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 16, cfg.d_model),
                          jnp.bfloat16)
    layer0 = jax.tree_util.tree_map(lambda p: p[0], params["layers"])
    out, aux = moe_layer(x, layer0["moe"], cfg)
    assert out.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) >= 0


def test_chunked_ce_matches_direct():
    from repro.models.layers import cross_entropy, lm_logits
    from repro.models.transformer import _chunked_ce
    cfg = get_config("internlm2-1.8b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(10), cfg)
    B, S = 3, 25
    x = jax.random.normal(jax.random.PRNGKey(11), (B, S, cfg.d_model),
                          jnp.float32)
    targets = jax.random.randint(jax.random.PRNGKey(12), (B, S), 0,
                                 cfg.vocab_size)
    mask = (jax.random.uniform(jax.random.PRNGKey(13), (B, S)) > 0.2
            ).astype(jnp.float32)
    got = _chunked_ce(x, params, cfg, targets, mask, chunk=7)
    logits = lm_logits(x, params["embed"], cfg)
    want = cross_entropy(logits, targets, mask)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4)


def test_sliding_window_cache_wraps_correctly():
    """Decode past the window: rolling buffer must equal full attention
    restricted to the window."""
    from repro.models import attention as A
    cfg = get_config("recurrentgemma-2b", reduced=True)
    cfg = cfg.replace(attention_impl="naive")
    key = jax.random.PRNGKey(14)
    params, _ = A.init_attention(key, cfg, jnp.float32)
    B, W = 1, cfg.local_window
    T = W + 6                                  # force wraparound
    x = jax.random.normal(jax.random.PRNGKey(15), (B, T, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    full, _ = A.attention_layer(x, params, cfg, pos, window=W)

    cache = A.init_cache(cfg, B, max_len=T, window=W, dtype=jnp.float32)
    outs = []
    for t in range(T):
        o, cache = A.attention_layer(
            x[:, t:t + 1], params, cfg, pos[:, t:t + 1],
            cache=cache, window=W)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-4, atol=2e-4)
