"""Explicit-collective DP trainer (parallel/dp.py): numerics vs the GSPMD
trainer, compression convergence — 8 virtual devices via subprocess."""
import os

from tests.test_multidevice import run_with_devices


def test_dp_step_matches_gspmd_trainer():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import OptimizerConfig
        from repro.parallel.dp import build_dp_train_step, init_dp_opt_state
        from repro.utils import make_mesh_compat
        from repro.training import build_train_step, init_state

        cfg = get_config("internlm2-1.8b", reduced=True)
        opt = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=50,
                              zero1=False, grad_clip=1.0, weight_decay=0.0)
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        key = jax.random.PRNGKey(0)
        state_ref = init_state(key, cfg, opt)
        gspmd_step = jax.jit(build_train_step(cfg, opt))

        dp_step, _ = build_dp_train_step(cfg, opt, mesh)
        params0 = state_ref["params"]
        dp_state = {"params": params0,
                    "opt": init_dp_opt_state(params0, mesh, opt)}

        batch = {"tokens": jax.random.randint(key, (8, 32), 0,
                                              cfg.vocab_size)}
        for i in range(3):
            state_ref, m_ref = gspmd_step(state_ref, batch)
            dp_state, m_dp = dp_step(dp_state, batch)
            assert abs(float(m_ref["loss"]) - float(m_dp["loss"])) < 1e-2, (
                i, float(m_ref["loss"]), float(m_dp["loss"]))
        for a, b in zip(jax.tree_util.tree_leaves(state_ref["params"]),
                        jax.tree_util.tree_leaves(dp_state["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


def test_dp_compressed_training_converges():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import OptimizerConfig
        from repro.parallel.dp import build_dp_train_step, init_dp_opt_state
        from repro.utils import make_mesh_compat

        cfg = get_config("internlm2-1.8b", reduced=True)
        opt = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=40,
                              zero1=False)
        mesh = make_mesh_compat((8,), ("data",))
        step, _ = build_dp_train_step(cfg, opt, mesh, compression="int8")
        key = jax.random.PRNGKey(0)
        from repro.models.registry import get_model
        params = get_model(cfg).init(key, cfg)
        state = {"params": params,
                 "opt": init_dp_opt_state(params, mesh, opt)}
        batch = {"tokens": jax.random.randint(key, (8, 32), 0,
                                              cfg.vocab_size)}
        losses = []
        for _ in range(12):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.3, losses
        assert np.isfinite(losses).all()
        print("OK", losses[0], losses[-1])
    """)
    assert "OK" in out
