"""The invariant analyzer, tested on itself: positive / negative /
suppressed fixtures per rule, plus a seeded corpus reproducing the PR-6
and PR-8 bugs verbatim from this repo's git history — re-introducing
either bug class must turn the exit code non-zero.
"""
import json
import textwrap

import pytest

from tools.analyze import RULES, run
from tools.analyze.__main__ import main as cli_main


def findings_for(tmp_path, code, name="snippet.py", select=None, root=None):
    path = tmp_path / name
    path.write_text(textwrap.dedent(code))
    return run([str(path)], select=select, root=str(root or tmp_path))


def rules_hit(findings):
    return {f.rule for f in findings}


def test_rule_registry_complete():
    assert {"deadline-truthiness", "lock-discipline",
            "replace-without-fsync", "transport-op-parity",
            "metric-catalog-drift", "swallowed-exception"} <= set(RULES)


# -- deadline-truthiness -----------------------------------------------------

def test_deadline_truthiness_positive(tmp_path):
    fs = findings_for(tmp_path, """\
        import time

        def wait(timeout=None):
            if timeout:
                deadline = time.monotonic() + timeout
            while timeout or True:
                pass
        """)
    assert [f.line for f in fs if f.rule == "deadline-truthiness"] == [4, 6]


def test_deadline_truthiness_tracks_assignment(tmp_path):
    fs = findings_for(tmp_path, """\
        import time

        def wait(timeout):
            deadline = (time.monotonic() + timeout) if timeout else None
            if deadline and time.monotonic() > deadline:
                return True
        """)
    # the ternary test and both tainted uses (`deadline` as an `and`
    # operand counts once; line 4's `if timeout` ternary is one finding)
    lines = [f.line for f in fs if f.rule == "deadline-truthiness"]
    assert 4 in lines and 5 in lines


def test_deadline_truthiness_negative(tmp_path):
    fs = findings_for(tmp_path, """\
        import time

        def wait(timeout=None, interval=1.0):
            deadline = (time.monotonic() + timeout) if timeout is not None \\
                else None
            if deadline is not None and time.monotonic() > deadline:
                return True
            if interval > 0.5:
                return False
            dead = [x for x in range(3) if x > timeout]
            if dead:                       # a list, not a time value
                return None
            changed = deadline != interval  # a bool, not a time value
            if changed:
                return None
        """)
    assert "deadline-truthiness" not in rules_hit(fs)


def test_deadline_truthiness_suppressed(tmp_path):
    fs = findings_for(tmp_path, """\
        def wait(timeout):
            # analyze: ok deadline-truthiness - timeout here is a bool flag
            if timeout:
                return 1
        """)
    assert "deadline-truthiness" not in rules_hit(fs)


# -- lock-discipline ---------------------------------------------------------

def test_lock_discipline_guarded_somewhere_guarded_everywhere(tmp_path):
    fs = findings_for(tmp_path, """\
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0          # __init__ writes are exempt

            def inc(self):
                with self._lock:
                    self.n += 1

            def reset(self):
                self.n = 0          # bare write: flagged
        """)
    assert [f.line for f in fs if f.rule == "lock-discipline"] == [13]


def test_lock_discipline_locked_helper_fixpoint(tmp_path):
    fs = findings_for(tmp_path, """\
        import threading

        class Log:
            def __init__(self):
                self._lock = threading.Lock()
                self.entries = []
                self._recover()     # __init__ call sites count as held

            def append(self, x):
                with self._lock:
                    self.entries.append(x)
                    self._roll()

            def _roll(self):
                self.entries = self.entries[-10:]   # caller holds the lock

            def _recover(self):
                self.entries = []
        """)
    assert "lock-discipline" not in rules_hit(fs)


def test_lock_discipline_sink_counter_clause(tmp_path):
    fs = findings_for(tmp_path, """\
        class BareSink:
            def write_batch(self, items):
                self.items += len(items)
                return 0
        """)
    assert [f.line for f in fs if f.rule == "lock-discipline"] == [3]


def test_lock_discipline_suppressed(tmp_path):
    fs = findings_for(tmp_path, """\
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0

            def inc(self):
                with self._lock:
                    self.n += 1

            def reset_before_start(self):
                # analyze: ok lock-discipline - called before threads spawn
                self.n = 0
        """)
    assert "lock-discipline" not in rules_hit(fs)


# -- replace-without-fsync ---------------------------------------------------

def test_replace_without_fsync_positive(tmp_path):
    fs = findings_for(tmp_path, """\
        import os

        def save(path, data):
            with open(path + ".tmp", "w") as f:
                f.write(data)
            os.replace(path + ".tmp", path)
        """)
    assert [f.line for f in fs if f.rule == "replace-without-fsync"] == [6]


def test_replace_without_fsync_negative(tmp_path):
    fs = findings_for(tmp_path, """\
        import os

        def save(path, data, fsync="always"):
            with open(path + ".tmp", "w") as f:
                f.write(data)
                f.flush()
                if fsync != "never":    # policy conditional still counts
                    os.fsync(f.fileno())
            os.replace(path + ".tmp", path)
        """)
    assert "replace-without-fsync" not in rules_hit(fs)


def test_replace_without_fsync_sequences_partition_a_function(tmp_path):
    # first rename is safe, the second write-rename sequence forgot both
    fs = findings_for(tmp_path, """\
        import os

        def save(path, data):
            with open(path + ".tmp", "w") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(path + ".tmp", path)
            with open(path + ".ptr.tmp", "w") as f:
                f.write(path)
            os.replace(path + ".ptr.tmp", path + ".ptr")
        """)
    assert [f.line for f in fs if f.rule == "replace-without-fsync"] == [11]


def test_replace_without_fsync_suppressed(tmp_path):
    fs = findings_for(tmp_path, """\
        import os

        def shuffle(a, b):
            # analyze: ok replace-without-fsync - same-process visibility only
            os.replace(a, b)
        """)
    assert "replace-without-fsync" not in rules_hit(fs)


# -- transport-op-parity -----------------------------------------------------

_TRANSPORT_FIXTURE = """\
import socket

_OPS = frozenset({{"produce", "read", "ping"{extra_allow}}})


class BrokerServer:
    def _dispatch(self, op, args, kwargs):
        if op == "ping":
            return "pong"
        if op == {special!r}:
            return None
        if op not in _OPS:
            raise ValueError(op)
        return getattr(self.broker, op)(*args, **kwargs)


class RemoteBroker:
    def _request(self, op, *args, **kwargs):
        return (op, args, kwargs)

    def produce(self, topic, value):
        return self._request("produce", topic, value)

    def read(self, rng):
        return self._request("read", rng)

    def ping(self):
        return self._request("ping") == "pong"
{extra_client}"""


def _transport_fixture(tmp_path, *, extra_allow="", special="ping",
                       extra_client=""):
    return findings_for(
        tmp_path,
        _TRANSPORT_FIXTURE.format(extra_allow=extra_allow, special=special,
                                  extra_client=extra_client),
        name="transport.py")


def test_transport_parity_clean(tmp_path):
    assert "transport-op-parity" not in rules_hit(_transport_fixture(tmp_path))


def test_transport_parity_client_issues_unlisted_op(tmp_path):
    fs = _transport_fixture(tmp_path, extra_client=(
        "\n    def fence(self, epoch):\n"
        "        return self._request(\"fence\", epoch)\n"))
    msgs = [f.message for f in fs if f.rule == "transport-op-parity"]
    assert any("`fence`" in m and "allow-list" in m for m in msgs)


def test_transport_parity_allowlisted_op_without_issuer(tmp_path):
    fs = _transport_fixture(tmp_path, extra_allow=', "promote"')
    msgs = [f.message for f in fs if f.rule == "transport-op-parity"]
    assert any("`promote`" in m and "no RemoteBroker method" in m
               for m in msgs)


def test_transport_parity_server_special_op_not_allowlisted(tmp_path):
    fs = _transport_fixture(tmp_path, special="stats")
    msgs = [f.message for f in fs if f.rule == "transport-op-parity"]
    assert any("`stats`" in m and "BrokerServer" in m for m in msgs)


# -- metric-catalog-drift ----------------------------------------------------

def _metric_tree(tmp_path, code_metric, doc_metric):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "observability.md").write_text(textwrap.dedent(f"""\
        # Observability

        ## Metric catalog

        | Name | Kind | Meaning |
        |------|------|---------|
        | `{doc_metric}` | counter | something |

        ## Other section

        | `not_a_metric_ref` | mentioned outside the catalog |
        """))
    pkg = tmp_path / "src" / "repro" / "data"
    pkg.mkdir(parents=True)
    (pkg / "layer.py").write_text(textwrap.dedent(f"""\
        def build(reg):
            return reg.counter("{code_metric}", "help text")
        """))
    return run([str(tmp_path / "src")], root=str(tmp_path))


def test_metric_catalog_in_sync(tmp_path):
    fs = _metric_tree(tmp_path, "ingest_polls_total", "ingest_polls_total")
    assert "metric-catalog-drift" not in rules_hit(fs)


def test_metric_catalog_missing_doc(tmp_path):
    fs = _metric_tree(tmp_path, "ingest_polls_total", "something_else")
    msgs = [f.message for f in fs if f.rule == "metric-catalog-drift"]
    assert any("`ingest_polls_total`" in m and "missing from" in m
               for m in msgs)
    assert any("`something_else`" in m and "nothing under src/repro/"
               in m for m in msgs)


# -- swallowed-exception -----------------------------------------------------

def test_swallowed_exception_positive(tmp_path):
    fs = findings_for(tmp_path, """\
        def f():
            try:
                risky()
            except:
                pass

        def g():
            try:
                risky()
            except Exception:
                pass
        """)
    assert [f.line for f in fs if f.rule == "swallowed-exception"] == [4, 10]


def test_swallowed_exception_negative(tmp_path):
    fs = findings_for(tmp_path, """\
        def f(log):
            try:
                risky()
            except OSError:
                pass                      # narrow type: fine
            try:
                risky()
            except Exception as e:
                log.warning("boom: %s", e)  # handled: fine
        """)
    assert "swallowed-exception" not in rules_hit(fs)


def test_swallowed_exception_suppressed(tmp_path):
    fs = findings_for(tmp_path, """\
        def f():
            try:
                risky()
            # analyze: ok swallowed-exception - teardown best-effort
            except Exception:
                pass
        """)
    assert "swallowed-exception" not in rules_hit(fs)


# -- seeded corpus: the shipped bugs, verbatim from git history --------------

# PR 8 (commit 851d42c) swept this out of IngestRunner.run_inline — the
# pre-fix hunk, verbatim: timeout=0 meant "wait forever".
PR8_RUN_INLINE_BUG = '''\
import time

class IngestRunner:
    def run_inline(self, timeout=None):
        """Pump until every source is exhausted (tests/benchmarks)."""
        deadline = (time.monotonic() + timeout) if timeout else None
        while not self.done:
            if self.pump() == 0:
                if deadline and time.monotonic() > deadline:
                    return
'''

# PR 6 (commit 10e1a65) added MetricsSink's lock — the pre-fix class,
# verbatim: observe() and write_batch() raced from delivery-lane threads.
PR6_METRICS_SINK_BUG = '''\
class MetricsSink:
    def __init__(self):
        self.batches = 0
        self.records = 0
        self.items = 0
        self.latencies = []

    def observe(self, info):
        self.batches += 1
        self.records += info.num_records
        self.latencies.append(info.processing_time)

    __call__ = observe

    def write_batch(self, items):
        self.items += len(items)
        return 0

    def report(self):
        if not self.latencies:
            return {"batches": 0, "records": 0, "items": self.items}
'''


def test_seeded_pr8_deadline_bug_detected(tmp_path):
    fs = findings_for(tmp_path, PR8_RUN_INLINE_BUG)
    lines = [f.line for f in fs if f.rule == "deadline-truthiness"]
    assert 6 in lines      # `if timeout else None`
    assert 9 in lines      # `if deadline and ...`


def test_seeded_pr6_metrics_sink_bug_detected(tmp_path):
    fs = findings_for(tmp_path, PR6_METRICS_SINK_BUG)
    lines = [f.line for f in fs if f.rule == "lock-discipline"]
    assert lines, "the PR-6 MetricsSink race must be flagged"
    assert 16 in lines     # write_batch counter


def test_reintroducing_the_fix_reverts_to_nonzero_exit(tmp_path, capsys):
    """Acceptance demo: fixture copies of the current (fixed) sources are
    clean; reverting a PR-8 deadline fix flips the CLI exit non-zero."""
    fixed = tmp_path / "fixed.py"
    fixed.write_text(textwrap.dedent("""\
        import time

        def run_inline(self, timeout=None):
            deadline = (time.monotonic() + timeout) \\
                if timeout is not None else None
            while not self.done:
                if deadline is not None and time.monotonic() > deadline:
                    return
        """))
    assert cli_main([str(fixed), "--root", str(tmp_path)]) == 0

    reverted = tmp_path / "reverted.py"
    reverted.write_text(PR8_RUN_INLINE_BUG)
    assert cli_main([str(reverted), "--root", str(tmp_path)]) == 1


# -- CLI ---------------------------------------------------------------------

def test_cli_json_output(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("def f(timeout):\n    if timeout:\n        pass\n")
    rc = cli_main([str(bad), "--json", "--root", str(tmp_path)])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["count"] == 1
    f = payload["findings"][0]
    assert (f["rule"], f["line"]) == ("deadline-truthiness", 2)
    assert f["path"].endswith("bad.py")


def test_cli_select_and_unknown_rule(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("def f(timeout):\n    if timeout:\n        pass\n")
    assert cli_main([str(bad), "--select", "swallowed-exception",
                     "--root", str(tmp_path)]) == 0
    assert cli_main([str(bad), "--select", "no-such-rule"]) == 2
    capsys.readouterr()


def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule in out


def test_syntax_error_is_a_finding(tmp_path):
    fs = findings_for(tmp_path, "def broken(:\n")
    assert rules_hit(fs) == {"syntax-error"}


# -- the real tree stays clean ----------------------------------------------

def test_repo_tree_is_clean():
    """`make analyze` parity: the shipped sources carry no findings (any
    intentional pattern is suppressed in place, with a reason)."""
    fs = run(["src", "tools"], root=".")
    assert fs == [], "\n".join(f.format() for f in fs)
