"""LagPolicy: deterministic hysteresis tests over a scripted metrics feed,
drive() wiring against IngestRunner signals, and one end-to-end run where a
deliberately slow consumer builds real lag and triggers a scale event."""
import pytest

from repro.core import Broker, Context, LagPolicy, StreamingContext
from repro.data import IngestConfig, IngestRunner, SyntheticRateSource


def make_policy(**kw):
    kw.setdefault("sustain", 3)
    kw.setdefault("cooldown", 5.0)
    kw.setdefault("clock", lambda: 0.0)      # tests always pass now=
    return LagPolicy(100, 10, **kw)


class StubController:
    """Duck-typed ElasticController: records scale calls, no jax devices."""

    def __init__(self, world=4, max_workers=8):
        self.world = world
        self.max_workers = max_workers
        self.calls = []

    def add_workers(self, n):
        self.world = min(self.max_workers, self.world + n)
        self.calls.append(("add", n))

    def fail_workers(self, n):
        assert n < self.world, "policy must never fail every worker"
        self.world -= n
        self.calls.append(("fail", n))


# -- scripted decision tests --------------------------------------------------

def test_scale_up_requires_sustained_lag():
    p = make_policy()
    assert [p.observe(150, now=t) for t in range(3)] == [0, 0, 1]


def test_lag_blip_does_not_scale():
    p = make_policy()
    # two highs, a dip into the band, two more highs: streak broken, no event
    feed = [150, 150, 50, 150, 150]
    assert [p.observe(lag, now=t) for t, lag in enumerate(feed)] == [0] * 5


def test_no_flapping_inside_hysteresis_band():
    p = make_policy()
    # noise between the watermarks (10 < lag < 100) never fires anything
    feed = [50, 90, 20, 60, 95, 15, 40, 80] * 3
    assert all(p.observe(lag, now=t) == 0 for t, lag in enumerate(feed))


def test_cooldown_suppresses_consecutive_events():
    p = make_policy(cooldown=5.0)
    assert [p.observe(150, now=t) for t in range(3)] == [0, 0, 1]
    # still overloaded, but inside the cooldown window: silence
    assert [p.observe(150, now=t) for t in (3.0, 4.0, 6.9)] == [0, 0, 0]
    # cooldown expired at t=7 (event at 2.0 + 5.0): streak restarts fresh
    assert [p.observe(150, now=t) for t in (7.0, 8.0, 9.0)] == [0, 0, 1]


def test_scale_down_on_drain():
    p = make_policy()
    assert [p.observe(0, now=t) for t in range(3)] == [0, 0, -1]


def test_shed_records_count_as_overload_even_with_low_lag():
    """Under drop/sample backpressure, overload shows up as shed records
    while lag stays bounded — shedding must drive scale-up."""
    p = make_policy()
    assert [p.observe(5, shed=64, now=t) for t in range(3)] == [0, 0, 1]


def test_step_size_and_history():
    p = make_policy(step=3, sustain=1, cooldown=0.0)
    assert p.observe(500, now=0) == 3
    assert p.observe(0, now=1) == -3
    assert [(o.lag, o.delta) for o in p.history] == [(500, 3), (0, -3)]


def test_band_validation():
    with pytest.raises(ValueError):
        LagPolicy(100, 100)
    with pytest.raises(ValueError):
        LagPolicy(100, 10, sustain=0)


# -- drive(): policy -> controller wiring -------------------------------------

def test_drive_scales_controller_with_clamps():
    ctl = StubController(world=7, max_workers=8)
    p = make_policy(step=4, sustain=1, cooldown=0.0)
    assert p.drive(ctl, lag=500, now=0) == 1     # clamped to max_workers
    assert ctl.world == 8
    assert p.drive(ctl, lag=500, now=1) == 0     # already at max
    ctl2 = StubController(world=2)
    p2 = make_policy(step=4, sustain=1, cooldown=0.0)
    assert p2.drive(ctl2, lag=0, now=0) == -1    # never fails the last worker
    assert ctl2.world == 1
    assert p2.drive(ctl2, lag=0, now=1) == 0     # nothing left to shed


def test_clamped_decision_does_not_burn_cooldown():
    """A scale-up decided while the controller is already at max applies
    nothing — and must not start a cooldown or reset the streak, so the
    policy reacts the moment headroom appears."""
    ctl = StubController(world=8, max_workers=8)
    p = make_policy(sustain=2, cooldown=100.0)
    assert p.drive(ctl, lag=500, now=0) == 0
    assert p.drive(ctl, lag=500, now=1) == 0     # decided +1, clamped to 0
    ctl.world = 7                                # a worker freed up
    assert p.drive(ctl, lag=500, now=2) == 1     # immediate, no cooldown tax
    assert ctl.calls == [("add", 1)]


def test_drive_reads_runner_lag_and_shed_deltas():
    broker = Broker()
    scripted = {"lag": 0}
    runner = IngestRunner(broker, lag_of=lambda topic: scripted["lag"])
    src = SyntheticRateSource(rate=1e9, total=1000)
    metrics = runner.add(src, IngestConfig(topic="t", policy="drop",
                                           max_pending=64))
    ctl = StubController(world=1)
    p = make_policy(sustain=2, cooldown=0.0)
    # quiet: lag low, nothing shed -> two drained ticks, but world=1 so the
    # scale-down is clamped to nothing
    assert p.drive(ctl, runner, now=0) == 0
    assert p.drive(ctl, runner, now=1) == 0
    assert ctl.calls == []
    # overload via shedding: bump the runner's drop counter between ticks
    metrics.dropped += 32
    assert p.drive(ctl, runner, now=2) == 0      # shed delta seen, streak 1
    metrics.dropped += 32
    assert p.drive(ctl, runner, now=3) == 1      # sustained -> scale up
    assert ctl.calls == [("add", 1)]
    # same cumulative counter, no NEW shedding: delta is 0, streak decays
    scripted["lag"] = 0
    assert p.drive(ctl, runner, now=4) == 0
    assert p.history[-1].shed == 0


# -- end to end ---------------------------------------------------------------

def test_slow_consumer_builds_lag_and_triggers_scale_event():
    """Real pipeline, deliberately slow consumer: the producer outruns the
    micro-batch loop, lag crosses the watermark for `sustain` consecutive
    batches, and the policy fires a scale-up on the controller."""
    broker = Broker()
    sc = StreamingContext(Context(), broker, max_records_per_partition=8)
    runner = IngestRunner(broker, consumer=sc)
    src = SyntheticRateSource(rate=1e9, total=400)
    runner.add(src, IngestConfig(topic="t", policy="block", max_pending=300,
                                 poll_batch=64))
    sc.subscribe(["t"])
    sc.foreach_batch(lambda rdd, info: rdd.count())
    ctl = StubController(world=1, max_workers=4)
    policy = LagPolicy(100, 10, sustain=3, cooldown=0.0)
    tick = 0
    while not (runner.done and sc.lag("t") == 0):
        runner.pump()                    # producer: up to 64 records/turn
        sc.run_one_batch()               # slow consumer: only 8/turn
        policy.drive(ctl, runner, now=float(tick))
        tick += 1
        assert tick < 1000, "pipeline never drained"
    assert ("add", 1) in ctl.calls       # overload scaled compute out
    assert ctl.world > 1
    assert max(o.lag for o in policy.history) >= 100
    # and the drain at the end walked it back down
    assert policy.history[-1].lag <= 10
