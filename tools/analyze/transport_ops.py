"""transport-op-parity: the wire protocol's three views must agree.

Adding a broker op touches three places in ``repro/data/transport.py``:
the ``_OPS`` allow-list (the server's security gate), the server dispatch
(``BrokerServer``), and the client method issuing it (``RemoteBroker``).
PR 7 and PR 8 each added five-plus ops and each had to hand-patch a
missed view — a drift the type system cannot see because ops travel as
strings. This rule cross-checks the actual source:

- every op the client issues (``self._request("op", ...)`` or a
  ``("op", args, kwargs)`` tuple handed to ``self._roundtrip``) must be
  in ``_OPS``;
- every op in ``_OPS`` must have a client-side issuer;
- every op the server special-cases by string comparison must be in
  ``_OPS``.

Triggers only on files named ``transport.py`` that define ``_OPS``.
"""
from __future__ import annotations

import ast
import os

from tools.analyze.core import (Finding, Project, ProjectChecker, Source,
                                register)


def _str_consts(node: ast.AST) -> set[str]:
    return {n.value for n in ast.walk(node)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)}


def _class_body(tree: ast.AST, name: str) -> ast.ClassDef | None:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _find_ops_literal(tree: ast.AST) -> tuple[set[str], int] | None:
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "_OPS"
                        for t in node.targets)):
            return _str_consts(node.value), node.lineno
    return None


def _client_issued_ops(cls: ast.ClassDef) -> set[str]:
    ops: set[str] = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        if not (isinstance(func, ast.Attribute)
                and isinstance(func.value, ast.Name)
                and func.value.id == "self"):
            continue
        if func.attr == "_request" and node.args:
            first = node.args[0]
            if isinstance(first, ast.Constant) and isinstance(first.value, str):
                ops.add(first.value)
        elif func.attr == "_roundtrip" and node.args:
            first = node.args[0]
            if (isinstance(first, ast.Tuple) and first.elts
                    and isinstance(first.elts[0], ast.Constant)
                    and isinstance(first.elts[0].value, str)):
                ops.add(first.elts[0].value)
    return ops


def _server_special_ops(cls: ast.ClassDef) -> set[str]:
    """Ops the server compares against the ``op`` variable by string."""
    ops: set[str] = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Compare):
            continue
        sides = [node.left] + list(node.comparators)
        if any(isinstance(s, ast.Name) and s.id == "op" for s in sides):
            for s in sides:
                ops |= _str_consts(s)
    return ops


@register
class TransportOpParity(ProjectChecker):
    name = "transport-op-parity"
    description = ("_OPS allow-list vs BrokerServer dispatch vs "
                   "RemoteBroker issuers must agree")

    def check_project(self, project: Project):
        for src in project.sources:
            if os.path.basename(src.path) != "transport.py":
                continue
            found = _find_ops_literal(src.tree)
            if found is None:
                continue
            allow, ops_line = found
            server = _class_body(src.tree, "BrokerServer")
            client = _class_body(src.tree, "RemoteBroker")
            if server is not None:
                for op in sorted(_server_special_ops(server) - allow):
                    yield Finding(
                        self.name, src.path, ops_line, 0,
                        f"BrokerServer dispatches op `{op}` but it is "
                        f"missing from the _OPS allow-list")
            if client is not None:
                issued = _client_issued_ops(client)
                for op in sorted(issued - allow):
                    yield Finding(
                        self.name, src.path, ops_line, 0,
                        f"RemoteBroker issues op `{op}` but it is missing "
                        f"from the _OPS allow-list (the server will "
                        f"reject it)")
                for op in sorted(allow - issued):
                    yield Finding(
                        self.name, src.path, ops_line, 0,
                        f"op `{op}` is allow-listed in _OPS but no "
                        f"RemoteBroker method issues it (dead surface "
                        f"or a missing client method)")
