"""Project invariant analyzer: AST lint passes grounded in shipped bugs.

Usage: ``python -m tools.analyze src/ tests/`` — exits non-zero on
findings. Rule catalog and suppression syntax: ``docs/static_analysis.md``.
"""
from tools.analyze.core import (Finding, Project, RULES, Source, render,
                                run)

# importing a checker module registers its rule(s)
from tools.analyze import (deadline, exceptions, fsync, locks,  # noqa: F401
                           metrics_catalog, transport_ops)

__all__ = ["Finding", "Project", "RULES", "Source", "render", "run"]
