"""lock-discipline: attributes guarded somewhere must be guarded everywhere.

PR 6's ``MetricsSink`` shipped with ``observe()`` and ``write_batch()``
racing on plain int counters and a ``latencies`` list from different
delivery-lane worker threads; the fix wrapped every surface in one lock.
This rule keeps that class of bug from coming back, in two clauses:

1. **consistency** — within a class, an attribute written under
   ``with self.<lock>`` in any method must not be written bare in another
   method. Private helpers whose every intra-class call site sits under
   the lock are treated as lock-held (fixpoint), matching the repo's
   ``_append_frames``-style "caller holds the lock" idiom; ``__init__``
   is exempt (no other thread can hold a reference yet).

2. **sink counters** — classes implementing the delivery-lane surfaces
   (``write_batch`` / ``observe``) run on lane worker threads by contract
   (`docs/data_subsystem.md`), so mutating writes (``+=``, ``append``,
   ``add`` ...) to ``self`` attributes inside those methods (and the
   ``_write_one`` hook they call) must happen under a ``with self.<lock>``
   block. This is the clause that catches the original, entirely
   lock-free ``MetricsSink``.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

from tools.analyze.core import (Checker, Finding, Source, dotted_self_path,
                                register)

_MUTATORS = {"append", "appendleft", "add", "extend", "insert", "update",
             "pop", "popleft", "remove", "discard", "clear", "setdefault"}

_SINK_METHODS = {"write_batch", "observe", "_write_one"}


def _is_lock_attr(name: str) -> bool:
    return "lock" in name.lower()


@dataclass
class _Write:
    path: str        # "self.attr" (base attribute of the dotted chain)
    node: ast.AST
    locked: bool
    mutator: bool    # via .append()/.add()/... rather than assignment


@dataclass
class _Method:
    name: str
    node: ast.AST
    writes: list[_Write] = field(default_factory=list)
    # self-method call sites: (callee name, was the call under a lock)
    calls: list[tuple[str, bool]] = field(default_factory=list)


def _base_attr(dotted: str) -> str:
    # "self.metrics.enqueued" guards/races on the `metrics` binding's
    # holder only through `self.metrics`; track the first hop
    parts = dotted.split(".")
    return ".".join(parts[:2])


class _MethodScan(ast.NodeVisitor):
    def __init__(self, method: _Method) -> None:
        self.m = method
        self.depth = 0  # with-lock nesting

    def visit_With(self, node: ast.With) -> None:
        locked = any(
            (p := dotted_self_path(item.context_expr)) is not None
            and _is_lock_attr(p)
            for item in node.items)
        if locked:
            self.depth += 1
        for stmt in node.body:
            self.visit(stmt)
        if locked:
            self.depth -= 1

    def _record_write(self, target: ast.AST, mutator: bool = False) -> None:
        dotted = dotted_self_path(target)
        if dotted is None or dotted == "self":
            return
        base = _base_attr(dotted)
        if _is_lock_attr(base):
            return
        self.m.writes.append(_Write(base, target, self.depth > 0, mutator))

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            if isinstance(tgt, ast.Subscript):
                tgt = tgt.value  # self.d[k] = v writes into self.d
            self._record_write(tgt)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        tgt = node.target
        if isinstance(tgt, ast.Subscript):
            tgt = tgt.value
        self._record_write(tgt)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._record_write(node.target)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute):
            dotted = dotted_self_path(func.value)
            if dotted is not None:
                if dotted == "self":
                    # self._helper(...) — an intra-class call site
                    self.m.calls.append((func.attr, self.depth > 0))
                elif func.attr in _MUTATORS:
                    self._record_write(func.value, mutator=True)
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        return  # nested defs run on unknown threads; out of scope

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_ClassDef = visit_FunctionDef


def _lock_held_methods(methods: dict[str, _Method]) -> set[str]:
    """Private methods whose every intra-class call site is under a lock
    (directly, or via another lock-held method). Fixpoint iteration."""
    held: set[str] = set()
    while True:
        changed = False
        for name, m in methods.items():
            if name in held or not name.startswith("_") or name == "__init__":
                continue
            sites = [(caller, locked)
                     for caller, cm in methods.items()
                     for callee, locked in cm.calls if callee == name]
            # a call from __init__ is as safe as a locked one: no other
            # thread holds a reference during construction
            if sites and all(locked or caller == "__init__"
                             or caller in held
                             for caller, locked in sites):
                held.add(name)
                changed = True
        if not changed:
            return held


@register
class LockDiscipline(Checker):
    name = "lock-discipline"
    description = ("attribute guarded by `with self._lock` in one method "
                   "written bare in another / unguarded sink counters")

    def check(self, src: Source):
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ClassDef):
                yield from self._check_class(src, node)

    def _check_class(self, src: Source, cls: ast.ClassDef):
        methods: dict[str, _Method] = {}
        for stmt in cls.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                m = _Method(stmt.name, stmt)
                scan = _MethodScan(m)
                for sub in stmt.body:
                    scan.visit(sub)
                methods[stmt.name] = m

        held = _lock_held_methods(methods)

        def effectively_locked(method: _Method, w: _Write) -> bool:
            return w.locked or method.name in held

        # clause 1: guarded-somewhere must be guarded-everywhere
        guarded = {w.path for m in methods.values() for w in m.writes
                   if effectively_locked(m, w) and m.name != "__init__"}
        for m in methods.values():
            if m.name == "__init__":
                continue  # construction happens-before publication
            for w in m.writes:
                if w.path in guarded and not effectively_locked(m, w):
                    how = "mutated" if w.mutator else "written"
                    yield Finding(
                        self.name, src.path, w.node.lineno,
                        w.node.col_offset,
                        f"`{w.path}` is {how} without the lock in "
                        f"`{cls.name}.{m.name}` but written under "
                        f"`with self.<lock>` elsewhere in the class")

        # clause 2: delivery-lane sink surfaces must guard counters.
        # `write_batch` is the Sink protocol's entry point — only classes
        # implementing it are handed to lanes (LagPolicy-style observers
        # with a solo `observe` stay on one thread).
        if "write_batch" not in methods:
            return
        for m in methods.values():
            if m.name not in _SINK_METHODS:
                continue
            for w in m.writes:
                if not effectively_locked(m, w):
                    yield Finding(
                        self.name, src.path, w.node.lineno,
                        w.node.col_offset,
                        f"`{w.path}` updated in `{cls.name}.{m.name}` "
                        f"without a lock; sink surfaces run on delivery-"
                        f"lane worker threads (PR-6 MetricsSink bug class)")
