"""Core of the project invariant analyzer: findings, suppressions, registry.

The analyzer is deliberately project-specific — every rule descends from a
bug this repo actually shipped and then fixed by hand (see
docs/static_analysis.md for the lineage). Checkers are stdlib-``ast`` only;
nothing here imports the code under analysis.

Two checker shapes:

- per-file: subclass :class:`Checker`, implement ``check(src)`` — called
  once per parsed source file;
- project-wide: subclass :class:`ProjectChecker`, implement
  ``check_project(project)`` — called once with every parsed file, for
  rules that cross files (transport-op parity, metric-catalog drift).

Suppressions: ``# analyze: ok <rule>[, <rule>...]`` on the finding's line
(or the line directly above it) silences those rules there; the ``ok-file``
variant anywhere in the file silences the rules for the whole file. Always
pair a suppression with a comment saying *why* the pattern is intentional.
"""
from __future__ import annotations

import ast
import io
import json
import os
import re
import tokenize
from dataclasses import asdict, dataclass, field


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def to_dict(self) -> dict:
        return asdict(self)


_SUPPRESS_RE = re.compile(
    r"analyze:\s*ok(?P<scope>-file)?\s*[:=]?\s*(?P<rules>[a-z0-9\-_]+(?:\s*,\s*[a-z0-9\-_]+)*)")


class Source:
    """One parsed Python file: AST + suppression table."""

    def __init__(self, path: str, text: str) -> None:
        self.path = path
        self.text = text
        self.tree = ast.parse(text, filename=path)
        self.line_ok: dict[int, set[str]] = {}
        self.file_ok: set[str] = set()
        self._scan_comments(text)

    def _scan_comments(self, text: str) -> None:
        try:
            tokens = tokenize.generate_tokens(io.StringIO(text).readline)
            for tok in tokens:
                if tok.type != tokenize.COMMENT:
                    continue
                m = _SUPPRESS_RE.search(tok.string)
                if not m:
                    continue
                rules = {r.strip() for r in m.group("rules").split(",")}
                if m.group("scope"):
                    self.file_ok |= rules
                else:
                    self.line_ok.setdefault(tok.start[0], set()).update(rules)
        except tokenize.TokenError:
            pass

    def suppressed(self, rule: str, line: int) -> bool:
        if rule in self.file_ok:
            return True
        for at in (line, line - 1):
            if rule in self.line_ok.get(at, ()):  # comment on or above the line
                return True
        return False


class Project:
    """Every parsed source plus the repo root (for docs lookups)."""

    def __init__(self, sources: list[Source], root: str = ".") -> None:
        self.sources = sources
        self.root = root

    def find(self, suffix: str) -> list[Source]:
        norm = suffix.replace(os.sep, "/")
        return [s for s in self.sources
                if s.path.replace(os.sep, "/").endswith(norm)]


class Checker:
    """Per-file rule. ``name`` is the rule id used in suppressions."""

    name = ""
    description = ""

    def check(self, src: Source):  # pragma: no cover - interface
        raise NotImplementedError
        yield


class ProjectChecker(Checker):
    """Cross-file rule: sees the whole project at once."""

    def check(self, src: Source):
        return ()

    def check_project(self, project: Project):  # pragma: no cover - interface
        raise NotImplementedError
        yield


RULES: dict[str, Checker] = {}


def register(cls: type) -> type:
    inst = cls()
    assert inst.name and inst.name not in RULES, f"bad rule {cls}"
    RULES[inst.name] = inst
    return cls


# -- file walking -----------------------------------------------------------

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "node_modules",
              ".claude"}


def iter_py_files(paths: list[str]):
    for path in paths:
        if os.path.isfile(path):
            if path.endswith(".py"):
                yield path
            continue
        for dirpath, dirnames, filenames in os.walk(path):
            dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS
                                 and not d.startswith("."))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def load_sources(paths: list[str]) -> tuple[list[Source], list[Finding]]:
    sources, errors = [], []
    for path in iter_py_files(paths):
        try:
            with open(path, encoding="utf-8") as f:
                text = f.read()
            sources.append(Source(path, text))
        except (SyntaxError, UnicodeDecodeError) as e:
            line = getattr(e, "lineno", 1) or 1
            errors.append(Finding("syntax-error", path, line, 0, str(e)))
    return sources, errors


def run(paths: list[str], select: set[str] | None = None,
        root: str = ".") -> list[Finding]:
    """Run every registered checker over ``paths``; returns surviving
    (non-suppressed) findings sorted by location."""
    sources, findings = load_sources(paths)
    project = Project(sources, root=root)
    by_path = {s.path: s for s in sources}
    checkers = [c for n, c in sorted(RULES.items())
                if select is None or n in select]
    for checker in checkers:
        raw = []
        for src in sources:
            raw.extend(checker.check(src))
        if isinstance(checker, ProjectChecker):
            raw.extend(checker.check_project(project))
        for f in raw:
            src = by_path.get(f.path)
            if src is not None and src.suppressed(f.rule, f.line):
                continue
            findings.append(f)
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


def render(findings: list[Finding], as_json: bool = False) -> str:
    if as_json:
        return json.dumps({"findings": [f.to_dict() for f in findings],
                           "count": len(findings)}, indent=2)
    lines = [f.format() for f in findings]
    lines.append(f"{len(findings)} finding(s)" if findings
                 else "analyze: clean")
    return "\n".join(lines)


# -- small AST helpers shared by checkers -----------------------------------

def dotted_self_path(node: ast.AST) -> str | None:
    """``self.a.b`` -> ``"self.a.b"``; None when the chain's base isn't
    the name ``self``."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name) and node.id == "self":
        return ".".join(["self"] + list(reversed(parts)))
    return None


def call_name(node: ast.Call) -> str | None:
    """Fully dotted callable name: ``os.replace(...)`` -> ``"os.replace"``."""
    func = node.func
    parts = []
    while isinstance(func, ast.Attribute):
        parts.append(func.attr)
        func = func.value
    if isinstance(func, ast.Name):
        parts.append(func.id)
        return ".".join(reversed(parts))
    return None
