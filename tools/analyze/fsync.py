"""replace-without-fsync: an atomic rename is only atomic if the data got
to disk first.

PR 5 added the checkpoint's ``fsync`` before rename and PR 8 closed the
same power-loss hole for durable-log segment creation: ``os.replace(tmp,
final)`` guarantees *which name* survives a crash, but without
``flush()`` + ``os.fsync()`` on the temp file the surviving name can
point at empty or torn bytes.

The rule: for every ``os.replace(...)`` call, the span of the enclosing
function since the *previous* ``os.replace`` (write-rename sequences
partition a function) must contain both a ``.flush()`` call and an
``os.fsync(...)`` call. An fsync under a policy conditional (``if
self.fsync != "never": ...``) counts — the degraded mode is an explicit
caller choice, which is exactly the contract `state.py` documents.
"""
from __future__ import annotations

import ast

from tools.analyze.core import Checker, Finding, Source, call_name, register


@register
class ReplaceWithoutFsync(Checker):
    name = "replace-without-fsync"
    description = "`os.replace` without a preceding flush+fsync of the temp file"

    def check(self, src: Source):
        for node in ast.walk(src.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_function(src, node)

    def _walk_shallow(self, node: ast.AST):
        """Walk without descending into nested defs — those are checked
        as functions in their own right."""
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                continue
            yield child
            yield from self._walk_shallow(child)

    def _check_function(self, src: Source, fn: ast.AST):
        calls: list[tuple[int, str, ast.Call]] = []
        for node in self._walk_shallow(fn):
            if isinstance(node, ast.Call):
                name = call_name(node)
                if name == "os.replace":
                    calls.append((node.lineno, "replace", node))
                elif name == "os.fsync":
                    calls.append((node.lineno, "fsync", node))
                elif name is not None and name.endswith(".flush"):
                    calls.append((node.lineno, "flush", node))
        calls.sort(key=lambda c: c[0])
        seen: set[str] = set()
        for line, kind, node in calls:
            if kind != "replace":
                seen.add(kind)
                continue
            missing = {"flush", "fsync"} - seen
            if missing:
                yield Finding(
                    self.name, src.path, node.lineno, node.col_offset,
                    f"os.replace without a preceding "
                    f"{' + '.join(sorted(missing))} in this write-rename "
                    f"sequence; a crash can publish torn or empty bytes")
            seen = set()  # next write-rename sequence starts fresh
