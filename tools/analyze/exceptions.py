"""swallowed-exception: a handler that eats everything hides real bugs.

A bare ``except:`` (which also catches ``KeyboardInterrupt`` and
``SystemExit``) and an ``except Exception: pass`` body both turn broker
corruption, torn frames, and lock-state bugs into silence — the delivery
runtime's whole point is that sink failures are *routed* (retry / skip /
dead-letter), never dropped on the floor.

Narrow handlers (``except OSError: pass`` on a teardown path) are fine
and never flagged. Intentional blanket handlers — e.g. rendering must
never kill the pipeline — carry an ``# analyze: ok swallowed-exception``
suppression with the reason in the comment.
"""
from __future__ import annotations

import ast

from tools.analyze.core import Checker, Finding, Source, register

_BROAD = {"Exception", "BaseException"}


def _broad_types(node: ast.AST | None) -> bool:
    if node is None:
        return True  # bare except
    if isinstance(node, ast.Name):
        return node.id in _BROAD
    if isinstance(node, ast.Tuple):
        return any(_broad_types(e) for e in node.elts)
    return False


def _body_swallows(body: list[ast.stmt]) -> bool:
    for stmt in body:
        if isinstance(stmt, ast.Pass):
            continue
        if (isinstance(stmt, ast.Expr)
                and isinstance(stmt.value, ast.Constant)):
            continue  # docstring / ellipsis
        return False
    return True


@register
class SwallowedException(Checker):
    name = "swallowed-exception"
    description = "bare `except:` or `except Exception: pass`"

    def check(self, src: Source):
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                yield Finding(
                    self.name, src.path, node.lineno, node.col_offset,
                    "bare `except:` also catches KeyboardInterrupt/"
                    "SystemExit; name the exceptions or use `except "
                    "Exception` with real handling")
            elif _broad_types(node.type) and _body_swallows(node.body):
                yield Finding(
                    self.name, src.path, node.lineno, node.col_offset,
                    "`except Exception: pass` swallows every failure "
                    "silently; handle, log, or narrow the type")
