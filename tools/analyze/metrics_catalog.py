"""metric-catalog-drift: every registered metric is documented, and the
docs never advertise a metric nothing registers.

``docs/observability.md``'s catalog is the operator contract — dashboards
and alerts are written against it. Each layer registers its instruments
at construction time via ``registry.counter/gauge/histogram("name",
...)``; this rule extracts those name literals from ``src/repro/`` and
diffs them against the catalog tables (the first cell of each ``|``-row
in the "Metric catalog" section, ``{label}`` suffixes stripped).
"""
from __future__ import annotations

import ast
import os
import re

from tools.analyze.core import (Finding, Project, ProjectChecker, register)

_KINDS = {"counter", "gauge", "histogram"}
_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")
_SPAN_RE = re.compile(r"`([^`]+)`")

DOC_RELPATH = os.path.join("docs", "observability.md")


def _code_metrics(project: Project) -> dict[str, tuple[str, int]]:
    """metric name -> (path, line) of the registration call."""
    out: dict[str, tuple[str, int]] = {}
    for src in project.sources:
        norm = src.path.replace(os.sep, "/")
        if "repro/" not in norm:
            continue
        for node in ast.walk(src.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _KINDS and node.args):
                first = node.args[0]
                if (isinstance(first, ast.Constant)
                        and isinstance(first.value, str)):
                    out.setdefault(first.value, (src.path, node.lineno))
    return out


def _doc_metrics(doc_path: str) -> dict[str, int]:
    """metric name -> line in the catalog section of observability.md."""
    with open(doc_path, encoding="utf-8") as f:
        lines = f.read().splitlines()
    out: dict[str, int] = {}
    in_catalog = False
    for i, line in enumerate(lines, start=1):
        if line.startswith("## "):
            in_catalog = line.lower().startswith("## metric catalog")
            continue
        if not in_catalog or not line.lstrip().startswith("|"):
            continue
        first_cell = line.lstrip().lstrip("|").split("|", 1)[0]
        for span in _SPAN_RE.findall(first_cell):
            name = re.sub(r"\{[^}]*\}", "", span).strip()
            if _NAME_RE.match(name):
                out.setdefault(name, i)
    return out


@register
class MetricCatalogDrift(ProjectChecker):
    name = "metric-catalog-drift"
    description = ("registered metric names vs docs/observability.md "
                   "catalog must agree both ways")

    def check_project(self, project: Project):
        doc_path = os.path.join(project.root, DOC_RELPATH)
        if not os.path.exists(doc_path):
            return  # fixture trees without docs: nothing to diff against
        code = _code_metrics(project)
        if not code:
            return  # analyzing a subtree with no registrations
        docs = _doc_metrics(doc_path)
        for name in sorted(set(code) - set(docs)):
            path, line = code[name]
            yield Finding(
                self.name, path, line, 0,
                f"metric `{name}` is registered here but missing from "
                f"the {DOC_RELPATH} catalog")
        for name in sorted(set(docs) - set(code)):
            yield Finding(
                self.name, doc_path, docs[name], 0,
                f"metric `{name}` is in the {DOC_RELPATH} catalog but "
                f"nothing under src/repro/ registers it")
