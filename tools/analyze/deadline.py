"""deadline-truthiness: a timeout of 0 must not behave like "no timeout".

PR 8 swept exactly this bug out of groups/ingest/delivery/dstream:

    deadline = (time.monotonic() + timeout) if timeout else None
    ...
    if deadline and time.monotonic() > deadline:

``timeout=0`` (meaning "give up immediately") is falsy, so both tests
silently turned it into "wait forever". The only correct spelling for
optional time values is ``is not None`` / ``is None``.

The rule flags truthiness tests — ``if``/``while``/ternary conditions,
``and``/``or`` operands, ``not x`` — whose subject is a timeout-like value:
a name whose ``_``-separated tokens include ``timeout``, ``deadline``,
``ttl``, ``expiry`` or ``interval``, an attribute ending in one, or a
variable assigned from an expression over such names. Comparisons
(``timeout > 0``, ``deadline is not None``) are fine and never flagged.
"""
from __future__ import annotations

import ast

from tools.analyze.core import Checker, Finding, Source, register

_TOKENS = {"timeout", "deadline", "ttl", "expiry", "interval"}


def _timey_name(name: str) -> bool:
    return any(tok in _TOKENS for tok in name.lower().split("_"))


def _subject_name(node: ast.AST) -> str | None:
    """The trailing identifier of a Name/Attribute, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


class _FunctionScan(ast.NodeVisitor):
    def __init__(self, src: Source) -> None:
        self.src = src
        self.findings: list[Finding] = []
        # names assigned from a timeout-like expression in this function
        self.tainted: set[str] = set()

    # -- taint tracking ----------------------------------------------------
    def _value_timey(self, node: ast.AST) -> bool:
        """Is this expression itself a timeout-like *value*? Comparisons,
        comprehensions and ordinary calls produce bools/collections/opaque
        results and are never timey, even when a timeout name appears
        inside them."""
        if isinstance(node, (ast.Name, ast.Attribute)):
            name = _subject_name(node)
            return name is not None and (_timey_name(name)
                                         or name in self.tainted)
        if isinstance(node, ast.BinOp):
            return self._value_timey(node.left) or self._value_timey(node.right)
        if isinstance(node, ast.UnaryOp):
            return self._value_timey(node.operand)
        if isinstance(node, ast.IfExp):
            return self._value_timey(node.body) or self._value_timey(node.orelse)
        if isinstance(node, ast.BoolOp):
            return any(self._value_timey(v) for v in node.values)
        if isinstance(node, ast.Call):
            # value-preserving builtins keep timeyness; anything else is
            # an opaque result
            return (isinstance(node.func, ast.Name)
                    and node.func.id in {"min", "max", "abs", "float"}
                    and any(self._value_timey(a) for a in node.args))
        return False

    def visit_Assign(self, node: ast.Assign) -> None:
        if self._value_timey(node.value):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self.tainted.add(tgt.id)
        self.generic_visit(node)

    # -- truthiness contexts ----------------------------------------------
    def _flag(self, node: ast.AST, ctx: str) -> None:
        name = _subject_name(node)
        direct = name is not None and (_timey_name(name)
                                       or name in self.tainted)
        # `x or default` with an arithmetic operand over a timeout-like
        # name (`self.batch_interval / 10 or 0.001`) conflates 0 the same
        # way a bare name does
        arith = (isinstance(node, ast.BinOp) and ctx == "or operand"
                 and self._value_timey(node))
        if direct or arith:
            label = name if name is not None else ast.unparse(node)
            self.findings.append(Finding(
                "deadline-truthiness", self.src.path,
                node.lineno, node.col_offset,
                f"truthiness test of timeout-like value `{label}` ({ctx}) "
                f"conflates 0 with None; compare `is not None` instead"))

    def _check_test(self, test: ast.AST, ctx: str) -> None:
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            self._flag(test.operand, f"not-test in {ctx}")
        else:
            self._flag(test, ctx)

    def visit_If(self, node: ast.If) -> None:
        self._check_test(node.test, "if condition")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        self._check_test(node.test, "while condition")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        self._check_test(node.test, "ternary condition")
        self.generic_visit(node)

    def visit_BoolOp(self, node: ast.BoolOp) -> None:
        ctx = "or operand" if isinstance(node.op, ast.Or) else "and operand"
        for value in node.values:
            self._flag(value, ctx)
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert) -> None:
        # tests assert truthiness of all sorts of things; stay quiet
        return

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        return  # nested defs get their own scan (and their own taint set)

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_ClassDef = visit_FunctionDef


@register
class DeadlineTruthiness(Checker):
    name = "deadline-truthiness"
    description = ("truthiness test on a timeout/deadline value "
                   "(0 becomes 'no limit')")

    def check(self, src: Source):
        for node in ast.walk(src.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scan = _FunctionScan(src)
                for stmt in node.body:
                    scan.visit(stmt)
                yield from scan.findings
