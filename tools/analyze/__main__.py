"""CLI for the project invariant analyzer.

    python -m tools.analyze src/ tests/           # text, exit 1 on findings
    python -m tools.analyze --json src/           # machine-readable
    python -m tools.analyze --select lock-discipline src/repro/data/
    python -m tools.analyze --list-rules
"""
from __future__ import annotations

import argparse
import sys

from tools.analyze import RULES, render, run


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analyze",
        description="project-specific static analysis (docs/static_analysis.md)")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files/directories to analyze (default: src tests)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON (per file:line, for CI "
                         "annotation)")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule names to run (default: all)")
    ap.add_argument("--root", default=".",
                    help="repo root for docs lookups (default: cwd)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, checker in sorted(RULES.items()):
            print(f"{name:24s} {checker.description}")
        return 0

    select = None
    if args.select:
        select = {s.strip() for s in args.select.split(",") if s.strip()}
        unknown = select - set(RULES)
        if unknown:
            print(f"unknown rule(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2

    paths = args.paths or ["src", "tests"]
    findings = run(paths, select=select, root=args.root)
    print(render(findings, as_json=args.json))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
