"""Docs link integrity: every ``docs/*.md`` referenced from README (and from
other docs) must exist, and every file in ``docs/`` must be reachable from
README — otherwise the doc is dead weight nobody can find.

Also cross-checks ``docs/static_analysis.md``: every rule named in its
"Rule catalog" table must exist in the ``tools.analyze`` registry and
vice versa, so the operator-facing catalog cannot drift from the code
the same way the metric catalog used to.

Run by ``make deps-check``. Exits non-zero with one line per problem.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_REF = re.compile(r"docs/[A-Za-z0-9_\-./]+?\.md")


def refs_in(path: str) -> set[str]:
    with open(path, encoding="utf-8") as f:
        return set(DOC_REF.findall(f.read()))


def check_rule_catalog(problems: list[str]) -> None:
    doc = os.path.join(REPO, "docs", "static_analysis.md")
    if not os.path.exists(doc):
        problems.append("docs/static_analysis.md missing (rule catalog)")
        return
    sys.path.insert(0, REPO)
    from tools.analyze import RULES

    documented: set[str] = set()
    in_catalog = False
    with open(doc, encoding="utf-8") as f:
        for line in f:
            if line.startswith("## "):
                in_catalog = line.strip() == "## Rule catalog"
            elif in_catalog and line.startswith("| `"):
                documented.add(line.split("`")[1])
    for rule in sorted(documented - set(RULES)):
        problems.append(f"docs/static_analysis.md catalogs `{rule}` but no "
                        "such rule is registered in tools.analyze")
    for rule in sorted(set(RULES) - documented):
        problems.append(f"tools.analyze registers `{rule}` but "
                        "docs/static_analysis.md's rule catalog omits it")


def main() -> int:
    problems: list[str] = []
    readme = os.path.join(REPO, "README.md")
    if not os.path.exists(readme):
        print("FAIL: README.md missing")
        return 1

    docs_dir = os.path.join(REPO, "docs")
    doc_files = {f"docs/{name}" for name in os.listdir(docs_dir)
                 if name.endswith(".md")}

    # forward: references resolve
    sources = [readme] + [os.path.join(REPO, d) for d in sorted(doc_files)]
    for src in sources:
        for ref in sorted(refs_in(src)):
            if not os.path.exists(os.path.join(REPO, ref)):
                rel = os.path.relpath(src, REPO)
                problems.append(f"{rel} references {ref}, which does not exist")

    # reverse: every doc is reachable from README
    for doc in sorted(doc_files - refs_in(readme)):
        problems.append(f"{doc} exists but README.md never references it")

    check_rule_catalog(problems)

    for p in problems:
        print(f"FAIL: {p}")
    if not problems:
        print(f"docs links ok ({len(doc_files)} docs, all referenced from "
              "README and resolving; analyzer rule catalog in sync)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
